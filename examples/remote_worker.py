"""Distributed HPO through the suggestion-service API (paper §2.1, §3.5).

One process serves the experiment (optimizer + system-of-record store);
any number of workers — on this host or others — drive the suggest/observe
loop against it over HTTP.  This is the scenario the protocol exists for:
the worker needs nothing but the service URL.

Run against a live service (started with ``repro serve-api --port 8765``):

    python examples/remote_worker.py --service http://HOST:8765 --workers 4

With no ``--service``, a demo service is started in-process first.

See API.md for the full v1 protocol (endpoints, schemas, error codes).
"""
import argparse
import tempfile
import threading
import time

from repro.api import CreateExperiment, HTTPClient, ObserveRequest, serve_api
from repro.core import ExperimentConfig, Param, Space


def objective(a):
    """Stand-in for a real training run (maximize)."""
    return -(a["lr"] - 0.3) ** 2 - 0.1 * (a["depth"] - 8) ** 2


def worker_loop(url: str, exp_id: str, name: str) -> int:
    """The entire worker contract: suggest -> evaluate -> observe."""
    client = HTTPClient(url)
    done = 0
    while True:
        batch = client.suggest(exp_id, 1)
        if not batch.suggestions:
            st = client.status(exp_id)
            if (st.observations >= st.budget
                    or st.state in ("complete", "stopped", "deleted")):
                return done
            time.sleep(0.02)    # others hold the remaining budget; retry
            continue
        s = batch.suggestions[0]
        client.observe(ObserveRequest(
            exp_id, s.suggestion_id, s.assignment,
            value=objective(s.assignment), trial_id=name))
        done += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", default=None,
                    help="URL of a running `repro serve-api`")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--budget", type=int, default=32)
    args = ap.parse_args()

    server = None
    url = args.service
    if url is None:
        server = serve_api(tempfile.mkdtemp()).start()
        url = server.url
        print(f"demo service started at {url}")

    client = HTTPClient(url)
    cfg = ExperimentConfig(
        name="remote-demo", budget=args.budget, parallel=args.workers,
        optimizer="random",
        space=Space([Param("lr", "double", 1e-3, 1.0, log=True),
                     Param("depth", "int", 2, 16)]))
    exp_id = client.create_experiment(
        CreateExperiment(config=cfg.to_json())).exp_id
    print(f"experiment {exp_id}: budget={cfg.budget}, "
          f"{args.workers} workers")

    counts = {}
    threads = [threading.Thread(
        target=lambda i=i: counts.__setitem__(
            i, worker_loop(url, exp_id, f"worker{i}")))
        for i in range(args.workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    st = client.status(exp_id)
    best = client.best(exp_id)
    print(f"done: {st.observations} observations "
          f"({', '.join(f'worker{i}: {n}' for i, n in sorted(counts.items()))})")
    print(f"best value {best.value:.4f} at {best.assignment}")
    if server is not None:
        server.shutdown()


if __name__ == "__main__":
    main()
