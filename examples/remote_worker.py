"""Distributed HPO through the suggestion-service API (paper §2.1, §2.5,
§3.5).

One process serves the experiment (optimizer + shared ASHA early-stopping
state + system-of-record store); any number of worker processes — on this
host or others — drive full schedulers against it over HTTP.  Each trial
streams intermediate metrics through ``ctx.report``, so pruning decisions
come from ONE service-side rung table no matter which worker runs the
trial, and a paused/stopped trial frees its slot for a better one.

Run against a live service (started with ``repro serve-api --port 8765``):

    python examples/remote_worker.py --service http://HOST:8765 --workers 2

With no ``--service``, a demo service is started in-process first.

See API.md for the full v1 protocol (endpoints, schemas, error codes) and
the "Trial events" section for report/decision semantics.
"""
import argparse
import tempfile
import threading
import time

from repro.api import CreateExperiment, HTTPClient, serve_api
from repro.core import ExperimentConfig, Orchestrator, Param, Space


def trial(a, ctx):
    """Stand-in for a real training run: improves toward its asymptote
    over 27 steps, reporting progress after each — the service answers
    continue/stop/pause at every ASHA rung crossing."""
    target = -(a["lr"] - 0.3) ** 2 - 0.1 * (a["depth"] - 8) ** 2
    start = ctx.resume_step or 0        # paused trials resume mid-curve
    for step in range(start + 1, 28):
        time.sleep(0.002)               # "training"
        value = target - (1.0 - step / 27.0)    # rises toward target
        ctx.report(step, value)         # -> POST .../trials/{tid}/report
    return target


def _cfg(budget, parallel):
    return ExperimentConfig(
        name="remote-demo", budget=budget, parallel=parallel,
        optimizer="random",
        space=Space([Param("lr", "double", 1e-3, 1.0, log=True),
                     Param("depth", "int", 2, 16)]),
        early_stop={"min_steps": 3, "eta": 3})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", default=None,
                    help="URL of a running `repro serve-api`")
    ap.add_argument("--workers", type=int, default=2,
                    help="number of scheduler processes to emulate")
    ap.add_argument("--parallel", type=int, default=2,
                    help="parallel bandwidth per worker")
    ap.add_argument("--budget", type=int, default=16)
    args = ap.parse_args()

    server = None
    url = args.service
    if url is None:
        server = serve_api(tempfile.mkdtemp()).start()
        url = server.url
        print(f"demo service started at {url}")

    client = HTTPClient(url)
    cfg = _cfg(args.budget, args.parallel)
    exp_id = client.create_experiment(
        CreateExperiment(config=cfg.to_json())).exp_id
    print(f"experiment {exp_id}: budget={cfg.budget}, "
          f"{args.workers} workers x {args.parallel} parallel, "
          f"ASHA rungs start at step {cfg.early_stop['min_steps']}")

    # each "worker" is a full scheduler with its own local store (trial
    # logs + checkpoints stay worker-side; observations/metrics/rungs are
    # service-side truth)
    def run_worker(i):
        orch = Orchestrator(tempfile.mkdtemp(prefix=f"worker{i}-"))
        orch.run(_cfg(args.budget, args.parallel), trial_fn=trial,
                 exp_id=exp_id, service=url)

    threads = [threading.Thread(target=run_worker, args=(i,))
               for i in range(args.workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    st = client.status(exp_id)
    best = client.best(exp_id)
    obs = server.backend.store.load_observations(exp_id) if server else None
    print(f"done: {st.observations} observations")
    if obs is not None:
        pruned = sum(1 for o in obs if o.metadata.get("pruned"))
        print(f"early-stopped (service-side shared ASHA): {pruned}")
    print(f"best value {best.value:.4f} at {best.assignment}")
    if server is not None:
        server.shutdown()


if __name__ == "__main__":
    main()
