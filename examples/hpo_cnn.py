"""The paper's §4 alpha-test use case: tune a 3-conv + 2-fc CNN classifier,
many evaluations with a fixed parallel bandwidth.

Paper numbers: 300 evaluations, 15 simultaneous, 1 GPU per model.  Default
here is scaled to CPU (30 evals, 5 parallel); pass --paper for the full 300/15.

  PYTHONPATH=src python examples/hpo_cnn.py [--paper] [--evals N] [--parallel K]
"""
import argparse
import tempfile

from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)
from repro.core.monitor import format_experiment_status
from repro.models.cnn import train_cnn


def trial(a, ctx):
    acc = train_cnn(a, steps=int(a.get("__steps__", 40)),
                    report=lambda s, v: ctx.report(s, v))
    ctx.log(f"accuracy={acc:.4f}")
    return acc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full paper scale: 300 evals, 15 parallel")
    ap.add_argument("--evals", type=int, default=30)
    ap.add_argument("--parallel", type=int, default=5)
    args = ap.parse_args(argv)
    budget = 300 if args.paper else args.evals
    parallel = 15 if args.paper else args.parallel

    orch = Orchestrator(tempfile.mkdtemp(prefix="orchestrate-"))
    orch.cluster_create({
        "cluster_name": "cnn-cluster",
        "pools": [{"name": "gpu", "resource": "tpu", "chips": parallel}]})
    cfg = ExperimentConfig(
        name="traffic-sign-cnn", budget=budget, parallel=parallel,
        optimizer="gp", goal="max",
        space=Space([
            Param("lr", "double", 1e-4, 3e-1, log=True),
            Param("momentum", "double", 0.0, 0.99),
            Param("fc_width", "int", 32, 256),
        ]),
        resources=Resources(pool="gpu", chips=1),
        early_stop={"min_steps": 9, "eta": 3})
    exp = orch.run(cfg, trial_fn=trial, cluster="cnn-cluster")
    print(format_experiment_status(exp, orch.status(exp)))


if __name__ == "__main__":
    main()
