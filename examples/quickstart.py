"""Quickstart: tune two hyperparameters with GP Bayesian optimization on a
local cluster, using the full Orchestrate workflow (cluster create -> run ->
status -> logs -> destroy).

  PYTHONPATH=src python examples/quickstart.py
"""
import math
import tempfile

from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)
from repro.core.monitor import format_experiment_status


def objective(a, ctx):
    """A noisy 2D function with optimum near lr=3e-3, dropout=0.2."""
    import random
    v = (-(math.log10(a["lr"]) + 2.5) ** 2
         - 4 * (a["dropout"] - 0.2) ** 2
         + random.Random(str(a)).gauss(0, 0.01))
    ctx.log(f"f(lr={a['lr']:.2e}, dropout={a['dropout']:.2f}) = {v:.4f}")
    return v


def main():
    orch = Orchestrator(tempfile.mkdtemp(prefix="orchestrate-"))
    orch.cluster_create({
        "cluster_name": "quickstart",
        "pools": [{"name": "cpu", "resource": "cpu", "chips": 8}]})

    cfg = ExperimentConfig(
        name="quickstart-gp", budget=24, parallel=4, optimizer="gp",
        space=Space([Param("lr", "double", 1e-5, 1e-1, log=True),
                     Param("dropout", "double", 0.0, 0.6)]),
        resources=Resources(pool="cpu", chips=1))
    exp = orch.run(cfg, trial_fn=objective, cluster="quickstart")

    print(format_experiment_status(exp, orch.status(exp)))
    print("\nlast log lines:")
    for line in list(orch.logs(exp))[-4:]:
        print(" ", line)
    orch.cluster_destroy("quickstart")
    print("\ncluster destroyed; experiment record kept in the store.")


if __name__ == "__main__":
    main()
