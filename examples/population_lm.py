"""Population training: P learning-rate/weight-decay configurations trained
SIMULTANEOUSLY in one vmapped program — the TPU-native form of the paper's
"15 models evaluated simultaneously" (DESIGN.md §2).

  PYTHONPATH=src python examples/population_lm.py [--trials 8] [--steps 40]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.vmap_trials import PopulationTrainer
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4))
    data = lambda t: {k: jnp.asarray(v) for k, v in pipe.batch_at(t).items()}

    rng = np.random.default_rng(0)
    assigns = [{"lr": float(10 ** rng.uniform(-4.5, -1.5)),
                "weight_decay": float(10 ** rng.uniform(-3, -0.5)),
                "seed": i} for i in range(args.trials)]

    trainer = PopulationTrainer(cfg, AdamWConfig())
    t0 = time.time()
    losses = trainer.train(assigns, data, steps=args.steps)
    dt = time.time() - t0
    order = np.argsort(losses)
    print(f"trained {args.trials} trials x {args.steps} steps in one "
          f"program: {dt:.1f}s ({args.trials * args.steps / dt:.1f} "
          f"trial-steps/s)")
    for rank, i in enumerate(order):
        a = assigns[i]
        print(f"  #{rank + 1}: loss={losses[i]:.4f} "
              f"lr={a['lr']:.2e} wd={a['weight_decay']:.2e}")


if __name__ == "__main__":
    main()
