"""End-to-end driver: train a ~120M-parameter LM for a few hundred steps
with checkpoints and deterministic data (deliverable (b) e2e example).

Default is a quick demo (--steps 30, tiny batch).  --paper runs the full
"few hundred steps at ~100M params" configuration (hours on this CPU
container; the same code jits under the production mesh on TPU).

  PYTHONPATH=src python examples/train_lm.py [--paper] [--resume]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def lm_100m():
    """~120M-param llama-style config derived from granite-8b."""
    return dataclasses.replace(
        get_config("granite-8b"), name="granite-120m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, dtype="float32", param_dtype="float32", remat="none")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="~120M params, 300 steps")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/orchestrate-train-lm")
    args = ap.parse_args(argv)

    if args.paper:
        import repro.launch.train as T
        import repro.configs.registry as R
        cfg = lm_100m()
        n = cfg.param_count()
        print(f"training {cfg.name}: {n / 1e6:.0f}M params")
        orig = R.get_config
        R.get_config = lambda name: cfg if name == cfg.name else orig(name)
        loss = train(cfg.name, steps=300, batch=8, seq=256, reduced=False,
                     lr=6e-4, warmup=30, ckpt_dir=args.ckpt_dir,
                     ckpt_every=50, resume=args.resume)
    else:
        loss = train("granite-8b", steps=args.steps, batch=4, seq=128,
                     reduced=True, ckpt_dir=args.ckpt_dir, ckpt_every=10,
                     resume=args.resume)
    print(f"final loss {loss:.4f}")


if __name__ == "__main__":
    main()
