#!/usr/bin/env bash
# CI entrypoint: tier-1 correctness, then the tier-2 perf gate.
#
#   scripts/ci.sh            # pytest -x -q && bench_check (non-zero on fail)
#
# ROADMAP.md documents both tiers.  Run on an otherwise idle machine:
# CPU contention alone inflates perf rows ~2x (the gate tolerates 3x).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== tier-2: perf gate =="
python scripts/bench_check.py
