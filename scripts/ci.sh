#!/usr/bin/env bash
# CI entrypoint: tier-1 correctness, then the tier-2 gate (multi-client
# contention tests + perf check).
#
#   scripts/ci.sh            # non-zero exit on any failure
#
# ROADMAP.md documents both tiers.  Run on an otherwise idle machine:
# CPU contention alone inflates perf rows ~2x (the gate tolerates 3x).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# includes tests/test_kernels_gp.py — dependency-free interpret-mode
# parity for every force_kernel dispatch path (tests/test_kernels.py
# skips wholesale when hypothesis is absent, so this is the tier-1
# Pallas-vs-oracle coverage)
python -m pytest -x -q

echo "== tier-2: multi-client contention tests =="
REPRO_CONTENTION=1 python -m pytest -q -m contention \
    tests/test_pipeline.py tests/test_batched_fit.py

echo "== tier-2: chaos fault-injection tests =="
# deterministic seeded fault plans (partition/heal/rebalance/failover);
# fencing invariants must hold under every interleaving — plus the
# transport plane's exactly-once batch replay under injected
# mid-response connection kills
REPRO_CHAOS=1 python -m pytest -q -m chaos \
    tests/test_fencing.py tests/test_transport.py

echo "== tier-2: perf gate =="
# --strict: a quick-sweep row missing from the committed BENCH_suggest.json
# fails CI (stale baseline after a bench rename/addition).  Gated rows
# include the fleet SLO (bench_fleet/suggest/k8c4: 8 experiments x 4
# clients through the HTTP router, gated on p50 — see API.md §Fleet).
bench_out=$(mktemp)
if ! python scripts/bench_check.py --strict | tee "$bench_out"; then
    echo
    echo "== bench delta summary (worst rows vs baseline) =="
    grep -E "x[0-9]+\.[0-9]+" "$bench_out" \
        | sed -E 's/^(.*) x([0-9]+\.[0-9]+)(.*)$/\2 \1 x\2\3/' \
        | sort -rn | head -10 | cut -d' ' -f2-
    rm -f "$bench_out"
    exit 1
fi
rm -f "$bench_out"
