"""Rank instructions by charged cost (bytes or ici) with trip multipliers."""
import gzip, re, sys, collections
sys.path.insert(0, "src")
from repro.distributed import hlo as H

path, mode = sys.argv[1], sys.argv[2]  # bytes | ici
with gzip.open(path, "rt") as f:
    text = f.read()
an = H.HloAnalyzer(text, 256)
comps = an.comps

# compute trip multiplier per computation by walking from entry
mult = collections.defaultdict(float)
def walk(name, m):
    comp = comps.get(name)
    if comp is None: return
    mult[name] += m
    for ins in comp.instrs:
        if ins.opcode == "while":
            mm = H._COND_BODY_RE.search(ins.line)
            if mm:
                trips = H._trip_count(comps.get(mm.group(1), H._Comp("")))
                walk(mm.group(2), m * trips)
walk(an.entry, 1.0)

rows = []
for cname, m in mult.items():
    comp = comps[cname]
    for ins in comp.instrs:
        if mode == "bytes":
            if ins.opcode in ("parameter","constant","tuple","get-tuple-element","bitcast","copy","while"):
                continue
            b = an._instr_bytes(ins, comp) * m
            if b > 0: rows.append((b, cname, ins.opcode, ins.line[:130]))
        else:
            kind = ins.opcode.replace("-start","")
            if kind in ("all-gather","all-reduce","reduce-scatter","all-to-all","collective-permute") and not ins.opcode.endswith("-done"):
                rb = H._shape_bytes(ins.result_type)
                grp = H._group_size(ins.line, 256)
                rows.append((H._ici_bytes(kind, rb, grp) * m, cname, ins.opcode, ins.line[:150]))
rows.sort(reverse=True)
total = sum(r[0] for r in rows)
print(f"total {mode}: {total:.3e}")
for b, cname, op, line in rows[:15]:
    opn = re.search(r'op_name="([^"]*)"', line)
    print(f"{b:.3e} ({100*b/total:4.1f}%) {op:18s} {(opn.group(1) if opn else line)[-110:]}")
