"""Re-score all dry-run cells from stored gzipped HLO with the current
analyzer (no recompilation)."""
import gzip, json, pathlib, sys
sys.path.insert(0, "src")
from repro.distributed.hlo import analyze
from repro.distributed.roofline import roofline_terms

out = pathlib.Path("results/dryrun")
hlo_dir = pathlib.Path("results/hlo")
n = 0
for j in sorted(out.glob("*.json")):
    rec = json.loads(j.read_text())
    if rec.get("skipped") or not rec.get("ok"):
        continue
    h = hlo_dir / (j.stem + ".txt.gz")
    if not h.exists():
        continue
    with gzip.open(h, "rt") as f:
        text = f.read()
    hlo = analyze(text, rec["n_devices"])
    model_flops = rec["roofline"]["model_flops_per_chip"]
    terms = roofline_terms(hlo, hlo["ici_bytes"],
                           model_flops_per_chip=model_flops)
    rec["collectives"] = {"counts": hlo["collective_counts"],
                          "ici_bytes": hlo["collective_bytes"],
                          "total_ici_bytes": hlo["ici_bytes"]}
    rec["roofline"] = terms
    j.write_text(json.dumps(rec, indent=1))
    n += 1
print(f"re-scored {n} cells")
