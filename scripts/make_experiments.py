"""Generate EXPERIMENTS.md from dry-run / perf artifacts.

Static narrative + tables rendered from:
  results/dryrun/        paper-faithful baseline (all 80 cells)
  results/dryrun_final/  beyond-paper optimized (all 80 cells)
  results/perf/iter*/    the hillclimb iteration artifacts
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASE = ROOT / "results" / "dryrun"
FINAL = ROOT / "results" / "dryrun_final"


def load(d, mesh):
    out = {}
    for p in sorted(d.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_row(r, opt=None):
    if r.get("skipped"):
        return None
    if not r.get("ok"):
        return f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |"
    rl = r["roofline"]
    cells = [r["arch"], r["shape"], rl["dominant"],
             f"{rl['t_compute_s']:.3f}", f"{rl['t_memory_s']:.3f}",
             f"{rl['t_collective_s']:.3f}",
             f"{rl.get('useful_ratio', 0):.2f}",
             f"{rl.get('roofline_fraction', 0):.4f}"]
    if opt is not None and opt.get("ok") and not opt.get("skipped"):
        cells.append(f"{opt['roofline'].get('roofline_fraction', 0):.4f}")
    elif opt is not None:
        cells.append("")
    return "| " + " | ".join(cells) + " |"


def roofline_table(mesh, with_final=True):
    base = load(BASE, mesh)
    final = load(FINAL, mesh) if with_final else {}
    hdr = ("| arch | shape | dominant | t_comp (s) | t_mem (s) | t_coll (s) "
           "| useful | frac (base) |" + (" frac (opt) |" if with_final else ""))
    sep = "|" + "---|" * (9 if with_final else 8)
    lines = [hdr, sep]
    skips = []
    for key in sorted(base):
        r = base[key]
        if r.get("skipped"):
            skips.append(key)
            continue
        row = fmt_row(r, final.get(key) if with_final else None)
        if row:
            lines.append(row)
    return "\n".join(lines), skips


def dryrun_summary(mesh):
    base = load(FINAL if FINAL.exists() else BASE, mesh)
    n_ok = sum(1 for r in base.values() if r.get("ok") and not r.get("skipped"))
    n_skip = sum(1 for r in base.values() if r.get("skipped"))
    n_fail = sum(1 for r in base.values() if not r.get("ok"))
    rows = ["| arch | shape | compile (s) | params | args/device (GiB) | "
            "HLO GFLOPs/chip | ICI GB/chip | collective ops |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(base):
        r = base[key]
        if r.get("skipped") or not r.get("ok"):
            continue
        coll = r["collectives"]["counts"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s', 0):.0f} | "
            f"{r['params'] / 1e9:.2f}B | "
            f"{r.get('arg_bytes_per_device', 0) / 2**30:.2f} | "
            f"{r['roofline']['hlo_flops_per_chip'] / 1e9:.0f} | "
            f"{r['roofline']['ici_bytes_per_chip'] / 1e9:.1f} | "
            f"{sum(int(v) for v in coll.values())} |")
    return n_ok, n_skip, n_fail, "\n".join(rows)


def perf_cell(path):
    r = json.loads(path.read_text())
    return r["roofline"]


def perf_table(arch):
    rows = [
        "| iteration | t_comp | t_mem | t_coll | dominant | frac |",
        "|---|---|---|---|---|---|"]
    stages = [("baseline", BASE / f"{arch}__train_4k__16x16.json")]
    for it in ("iter1", "iter2", "iter2b", "iter3", "iter3b"):
        p = ROOT / "results" / "perf" / it / f"{arch}__train_4k__16x16.json"
        if p.exists():
            stages.append((it, p))
    fp = FINAL / f"{arch}__train_4k__16x16.json"
    if fp.exists():
        stages.append(("final", fp))
    for name, p in stages:
        rl = perf_cell(p)
        rows.append(f"| {name} | {rl['t_compute_s']:.3f} | "
                    f"{rl['t_memory_s']:.3f} | {rl['t_collective_s']:.3f} | "
                    f"{rl['dominant']} | "
                    f"{rl.get('roofline_fraction', 0):.4f} |")
    return "\n".join(rows)


def main():
    pod_table, skips = roofline_table("16x16")
    mp_table, _ = roofline_table("2x16x16")
    n_ok, n_skip, n_fail, dsum = dryrun_summary("16x16")
    n_ok2, n_skip2, n_fail2, _ = dryrun_summary("2x16x16")

    text = TEMPLATE.format(
        pod_table=pod_table, mp_table=mp_table, dsum=dsum,
        n_ok=n_ok, n_skip=n_skip, n_fail=n_fail,
        n_ok2=n_ok2, n_skip2=n_skip2, n_fail2=n_fail2,
        skips=", ".join(f"{a}×{s}" for a, s in skips),
        perf_moe=perf_table("granite-moe-3b-a800m"),
        perf_ds=perf_table("deepseek-v2-lite-16b"),
        perf_phi=perf_table("phi3-medium-14b"))
    (ROOT / "EXPERIMENTS.md").write_text(text)
    print("wrote EXPERIMENTS.md")


TEMPLATE = """# EXPERIMENTS — Orchestrate-JAX

All artifacts are reproducible:
- dry-run cells: `PYTHONPATH=src python -m repro.launch.dryrun --all` (JSON per
  cell under `results/dryrun*/`, gzipped compiled HLO under `results/*hlo*/`)
- benchmarks: `PYTHONPATH=src python -m benchmarks.run`
- tests: `PYTHONPATH=src pytest tests/`

Hardware model (TPU v5e target; this container is CPU-only so the dry-run
numbers are derived from the compiled artifact, not wall clock): 197 TFLOP/s
bf16/chip, 819 GB/s HBM/chip, 50 GB/s/link ICI.

## §Dry-run

Every (architecture × input-shape × mesh) cell is lowered AND compiled with
`jax.jit(step, in_shardings=…).lower(...).compile()` against 512 placeholder
host devices; `memory_analysis()` / `cost_analysis()` are captured in the
JSON artifacts together with a trip-count-aware HLO analysis
(`repro.distributed.hlo` — XLA's own `cost_analysis()` counts `while` bodies
once, which under-reports every scanned model; verified and documented in
`tests/test_hlo.py`).

Results:
- single-pod 16×16 (256 chips): **{n_ok} cells compile OK, {n_skip}
  documented skips, {n_fail} failures**
- multi-pod 2×16×16 (512 chips, `pod` axis): **{n_ok2} OK, {n_skip2} skips,
  {n_fail2} failures** — the pod axis shards (data-parallel across pods with
  sequence-parallel fallback inside each pod when batch < chips).

Documented skips (`long_500k` on pure full-attention archs, per DESIGN.md):
{skips}.

`args/device` below is the exact per-device bytes of the sharded inputs
(params + optimizer state for train; params + KV cache for decode), computed
from the shardings — every cell fits the 16 GiB HBM of a v5e chip.

{dsum}

## §Roofline

Per-chip terms from the compiled artifact (single-pod mesh):
`t_comp = HLO_FLOPs / 197e12`, `t_mem = HLO_bytes / 819e9`,
`t_coll = ring-model ICI bytes / 50e9` (collective bytes parsed per op from
the compiled HLO with replica-group sizes and loop trip multipliers —
`reduce-scatter` charged `in_bytes·(n-1)/n`, `all-reduce` `2·bytes·(n-1)/n`,
etc.).  `useful` = MODEL_FLOPS / HLO_FLOPs where MODEL_FLOPS = 6·N·D (train)
or 2·N·D (prefill/decode), N_active for MoE — it exposes remat recompute
(full-remat trains sit near 0.7 ≈ 3/4.2 passes) and any padding/replication
waste.  `frac` = (MODEL_FLOPS/peak) / max(t_comp, t_mem, t_coll) — the score
we hillclimb.  `frac (base)` is the paper-faithful baseline, `frac (opt)` the
beyond-paper optimized build (same table regenerated after §Perf).

### Single-pod (16×16, 256 chips)

{pod_table}

### Multi-pod (2×16×16, 512 chips)

{mp_table}

Reading the table:
- **train_4k** cells are the meaningful MFU story (the paper's workload is
  parallel *training* trials).  Dense 8-14B archs reach frac 0.21–0.39
  baseline; the gap to 1.0 decomposes into remat recompute (×1.33), the
  memory term (activation + f32-backward traffic — see §Perf iteration 3),
  and FSDP parameter gathers.
- **decode** cells are latency cells: model FLOPs per step are tiny, so frac
  ≈ 0 by construction; the deliverable there is that the KV cache shards
  (batch × sequence) and the per-step collectives are small (see ICI column).
- **whisper / xlstm** are small models on 256 chips — communication floors
  dominate (they would be served/trained on sub-slices in production, which
  the HPO layer's slice allocator does).
- the sLSTM recurrence (xlstm train) performs a per-timestep gradient
  all-reduce for its recurrent weights — a real architectural cost of
  batch-sharded BPTT; the fix (per-device grad accumulation inside a
  shard_map, one psum at exit) is noted as future work in DESIGN.md.

## §Perf — hypothesis → change → measure log

Method: every change is driven by ranking the compiled HLO's instructions by
charged bytes / ICI traffic (`scripts/hlo_top.py`).  The three hillclimbed
cells (chosen per the assignment: worst roofline fraction, most
collective-bound, most representative dense-training workload):

### Cell 1: granite-moe-3b-a800m × train_4k (worst frac: 0.001)

{perf_moe}

- **Iteration 1 — MoE dispatch anchoring.** *Hypothesis*: the top-2 HLO
  collectives (57% of 4.1 TB/chip ICI) are a batch-REPLICATED `(E,B,C,d)`
  f32 dispatch buffer — XLA's scatter partitioner gives up on the vmapped
  scatter and replicates; anchoring scatter operands/results to the batch
  sharding removes it.  Predicted t_coll 82→<2 s.  *Result*: 81.97→1.26 s
  (65×) and t_mem 18.6→2.8 s.  **Confirmed** (`models/moe.py` anchors).
- **Iteration 3b — bf16 probability chain** (shared with cell 3):
  t_mem 2.90→2.67 s.  Confirmed (small).
- **Iteration 4 — sequence-local routing.** *Hypothesis*: under meshes that
  shard the sequence axis (multi-pod train, all prefills), the per-sequence
  routing cumsum crosses shards; gathering S once at MoE entry (one reshard
  in/out) removes it.  *Result*: prefill_32k frac 0.0045→0.006 (16×16) and
  0.0044→0.006 (2×16×16); multi-pod train only 13.9→12.3 s t_coll —
  **partially confirmed**: the multi-pod train residual (58.9% of ICI) is
  the f32 expert-gradient all-reduce over the 32-way batch replicas (the
  same backend artifact as iterations 2/2b, magnified by the replication
  degree — see the HLO breakdown in scripts/hlo_top.py output).
- Residual bound (single-pod): memory (dispatch buffers + expert weight
  reads — real MoE traffic).  frac 0.001 → **0.041** (41×).

### Cell 2: deepseek-v2-lite-16b × train_4k (most collective-bound: 76 s)

{perf_ds}

- **Iteration 1** (same anchoring): t_coll 76.2→5.25 s (14.5×), t_mem
  17.95→3.14 s.  **Confirmed.**
- **Iteration 4** (sequence-local routing, shared with cell 1): prefill_32k
  frac 0.0075→0.016 (2.1×).
- Residual t_coll ≈ 47% per-layer f32 gradient reductions + bf16 expert
  weight FSDP gathers.  Iterations 2/2b below attacked the former and were
  refuted on this backend; true expert parallelism (shard_map + all_to_all
  token routing) is the next lever and is left documented.
- frac 0.004 → **0.063** (16×).

### Cell 3: phi3-medium-14b × train_4k (representative dense train)

{perf_phi}

- **Iteration 2 — bf16 gradient reduction.** *Hypothesis*: 47% of ICI is a
  per-layer f32 all-reduce tuple of weight gradients; differentiating w.r.t.
  the bf16-cast params moves the reduction to bf16 (2×).  *Result*: compiled
  HLO byte-identical — XLA re-converts to f32 before reducing (the consumer
  is f32 Adam).  **Refuted** on XLA:CPU.
- **Iteration 2b — gradient sharding constraints** (reduce-scatter instead
  of all-reduce): also byte-identical — the all-reduce→reduce-scatter
  rewrite does not fire inside `while` bodies on this backend (it does on
  the TPU pipeline; we claim nothing and record the negative result).
- **Iteration 3 — bf16 scores (first attempt).** *Hypothesis*: f32 score
  tensors ≈50% of the 5.4 TB/chip memory traffic; storing scores bf16 halves
  it.  *Result*: t_mem 6.18→6.39 s — **refuted**: the `astype(f32)` inside
  the exp chain forced f32 residuals into the backward.
- **Iteration 3b — full-bf16 probability chain** (max-subtracted exp kept
  entirely in compute dtype, f32 only for the normalizer accumulation):
  t_mem 6.39→5.92 s, decode-consistency tests unchanged.  **Confirmed**
  (the remaining f32 traffic is backward matmul partials and partitioner
  reshard chains; the structural fix is the Pallas flash-attention kernel
  (`kernels/flash_attention.py`), which never materializes scores — it is
  validated in interpret mode but cannot be compiled into the CPU dry-run,
  so no number is claimed for it here).
- Earlier global fixes recorded for completeness (applied before the
  baseline sweep, visible in all tables): bf16 pre-cast of parameters
  outside the layer scan (halves FSDP gather traffic vs naive f32 gathers),
  small-leaf replication (min 1M elements — kills per-timestep gathers of
  recurrent weights), activation/scan-carry sharding anchors (kills
  "involuntary full rematerialization" reshard storms).
- frac 0.279 → **0.309**.

### Stopping criterion

Three consecutive <5% iterations on the dominant term of cell 3 (2, 2b, 3)
against a structural backend limitation; cells 1–2 improved 41×/16× and
their residual is real MoE data movement.  Remaining headroom documented:
expert parallelism via shard_map all_to_all (deepseek), Pallas flash
attention on real TPUs (dense archs), shard_map BPTT gradient accumulation
(xlstm).

## §Paper claims (Orchestrate itself)

The paper's own quantitative surface is §4: 300 evaluations at 15-way
parallelism on a 3-conv/2-fc CNN, plus the workflow (six CLI verbs, status/
logs UX, failed-observation accounting).  Reproduced:

- `examples/hpo_cnn.py --paper` runs 300 evals / 15 parallel of the same
  CNN shape (synthetic stand-in for GTSRB; offline container).
- `benchmarks/bench_parallel.py`: wall-clock speedup of the scheduler at
  1/5/15 workers under lognormal trial durations — near-linear (see
  bench_output.txt; efficiency ≥0.9 at 15 workers with 60 trials).
- `benchmarks/bench_scheduler.py`: straggler speculation is measured under
  saturated slots (budget ≫ parallel), where it correctly does NOT fire
  mid-experiment (no free slot to speculate into) — wall-clock parity in
  bench_output.txt; the mechanism itself (3× median detection, first
  finisher wins, loser cancelled) is asserted in
  `tests/test_scheduler.py::test_straggler_speculation_wins` (beats a 4 s
  straggler tail by >2 s).
- Fig. 4 UX (status screen, aggregated `logs --follow`, failed-observation
  counts) is reproduced by the CLI (`tests/test_store_cli.py` asserts the
  full lifecycle, including cluster-destroy ≠ experiment-delete).
- `benchmarks/bench_population.py`: the beyond-paper vmap population
  executor trains 8 trials in one program ~2× faster than sequentially even
  on CPU (on TPU the win is the MXU batching; equivalence to sequential
  training is exact — `tests/test_population.py`, diff < 1e-5).
"""


if __name__ == "__main__":
    sys.exit(main())
