#!/usr/bin/env python
"""Tier-2 perf gate (see ROADMAP.md).

Runs the quick hot-path benchmark sweep, writes fresh rows, and compares
them against the committed ``BENCH_suggest.json`` baseline: any gated row
slower than ``tolerance``x its baseline fails the check (exit 1).  Gated
rows are the suggestion/service hot path — including ALL the
``bench_service/suggest_contended_*`` pipeline rows: since ISSUE 5
(shared fit executor + adaptive refit budget + sparse speculative
posterior) the c32 rows are unimodal and gateable; only the
deliberately-slow synchronous reference row stays ungated.  Scheduler
throughput is reported but not gated (too machine-dependent).

Row values are noise-robust (ISSUE 5): single-path rows gate on the
min-of-k sample, contended rows on their p50; the fresh p50/p90 spread
is printed alongside so bimodality is visible at a glance.

``--strict`` additionally fails when the quick sweep produces rows the
committed baseline does not know about — a stale baseline after a bench
rename/addition (scripts/ci.sh runs with ``--strict``; refresh with
``--update``).

Usage:
  PYTHONPATH=src python scripts/bench_check.py             # gate vs baseline
  PYTHONPATH=src python scripts/bench_check.py --update    # refresh baseline
  PYTHONPATH=src python scripts/bench_check.py --strict    # CI: also fail
                                                           # on missing rows
"""
import argparse
import json
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
GATED_PREFIXES = ("bench_suggest/gp", "bench_service/", "bench_fleet/",
                  "bench_fit/", "bench_transport/", "bench_ask/")
# Reported but never gated: the synchronous (prefetch=0) row is the
# deliberately-slow pre-pipeline reference, not a served path; the
# rebalance row tracks the suggest tail during a live shard-add handover
# (drain -> adopt -> transfer), which is environment-sensitive by nature;
# the raw c32 contended rows oversubscribe a small host by design (32
# client threads on a 1-core container is pure scheduler noise — see
# ROADMAP.md's contended-row noise analysis), so the gate rides the
# ``cauto`` rows, which pin the client count to min(4·cores, 32).
UNGATED_ROWS = ("bench_service/suggest_contended_sync/c8",
                "bench_fleet/rebalance/k8",
                "bench_service/suggest_contended_local/c32",
                "bench_service/suggest_contended_http/c32")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO / "BENCH_suggest.json"))
    ap.add_argument("--out", default=None,
                    help="where to write fresh rows (default: temp only, "
                         "or the baseline itself with --update)")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="fail when a gated row exceeds this multiple of "
                         "its baseline (default 3.0 — the gate catches "
                         "order-of-magnitude regressions; run on an idle "
                         "machine, 2x noise under CPU contention is real)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline with the fresh rows")
    ap.add_argument("--strict", action="store_true",
                    help="also fail when the baseline is missing rows the "
                         "quick sweep produces (stale after a bench "
                         "rename — refresh with --update)")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO))
    sys.path.insert(0, str(REPO / "src"))
    from benchmarks import run as bench_run

    collected = bench_run.collect(quick=True)
    fresh, fresh_stats = collected["rows"], collected["stats"]
    prior_rows = {}
    if pathlib.Path(args.baseline).exists():
        try:
            prior_rows = json.loads(
                pathlib.Path(args.baseline).read_text()).get("rows") or {}
        except json.JSONDecodeError:
            pass
    out = args.out or (args.baseline if args.update else None)
    if out:
        # merge into an existing baseline: the quick sweep covers only a
        # subset of rows (no h150 etc.) and must not drop the rest — and
        # keep run.py's schema (created timestamp, quick flag) intact
        payload = {"schema": 2, "unit": "us", "quick": True,
                   "rows": {}, "stats": {}}
        if pathlib.Path(out).exists():
            try:
                prior = json.loads(pathlib.Path(out).read_text())
                if isinstance(prior.get("rows"), dict):
                    payload.update(prior)
            except json.JSONDecodeError:
                pass
        payload["rows"] = dict(payload["rows"], **fresh)
        payload["stats"] = dict(payload.get("stats") or {}, **fresh_stats)
        payload["schema"] = 2
        payload["created"] = time.time()
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {out} ({len(fresh)} refreshed, "
              f"{len(payload['rows'])} total rows)")
    if args.update:
        # per-row before/after delta table: --update silently rewriting
        # the committed numbers is how a regression sneaks into the
        # baseline — make what changed explicit at refresh time
        print(f"\n{'row':44s} {'before':>10s} {'after':>10s} {'delta':>8s}")
        for name, us in sorted(fresh.items()):
            ref = prior_rows.get(name)
            if ref:
                pct = (us - ref) / ref * 100.0
                delta = f"{pct:+.0f}%"
                before = f"{ref:.0f}us"
            else:
                delta, before = "new", "-"
            print(f"{name:44s} {before:>10s} {us:>9.0f}us {delta:>8s}")
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"no baseline at {base_path}; run with --update to create one")
        return 0
    baseline = json.loads(base_path.read_text())["rows"]

    failures, missing = [], []
    for name, us in sorted(fresh.items()):
        ref = baseline.get(name)
        gated = (any(name.startswith(p) for p in GATED_PREFIXES)
                 and name not in UNGATED_ROWS)
        spread = fresh_stats.get(name)
        note = (f"  p50={spread['p50']:.0f} p90={spread['p90']:.0f}"
                if spread else "")
        if ref:
            ratio = us / ref
            note += f"  baseline={ref:.0f}us  x{ratio:.2f}"
            if gated and ratio > args.tolerance:
                note += "  REGRESSION"
                failures.append(name)
        else:
            # not yet in the committed baseline (e.g. a freshly added
            # contention row): run --update to start tracking it —
            # --strict (CI) treats this as a stale-baseline failure
            note += "  (new; no baseline)"
            missing.append(name)
        print(f"{name:44s} {us:10.0f}us{note}")
    if failures:
        print(f"\nPERF GATE FAILED ({len(failures)} rows > "
              f"{args.tolerance}x baseline): {', '.join(failures)}")
        return 1
    if args.strict and missing:
        print(f"\nPERF GATE FAILED (stale baseline: {len(missing)} rows "
              f"missing — run scripts/bench_check.py --update): "
              f"{', '.join(missing)}")
        return 1
    print("\nperf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
