#!/usr/bin/env python
"""Tier-2 perf gate (see ROADMAP.md).

Runs the quick hot-path benchmark sweep, writes fresh rows, and compares
them against the committed ``BENCH_suggest.json`` baseline: any gated row
slower than ``tolerance``x its baseline fails the check (exit 1).  Gated
rows are the suggestion/service hot path — including the
``bench_service/suggest_contended_*`` pipeline rows (p50 suggest latency
under 1/8/32-way client contention, ISSUE 4); scheduler throughput is
reported but not gated (too machine-dependent).

Usage:
  PYTHONPATH=src python scripts/bench_check.py             # gate vs baseline
  PYTHONPATH=src python scripts/bench_check.py --update    # refresh baseline
"""
import argparse
import json
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
GATED_PREFIXES = ("bench_suggest/gp", "bench_service/")
# Reported but never gated: the c32 contention rows run the service at
# ~4x the GP's intrinsic suggestion throughput, so they are bimodal by
# design (all-hit us vs miss-queueing ~100ms depending on how the fleet
# staggers); the sync row is the deliberately-slow pre-pipeline
# reference, not a served path.
UNGATED_ROWS = ("bench_service/suggest_contended_local/c32",
                "bench_service/suggest_contended_http/c32",
                "bench_service/suggest_contended_sync/c8")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO / "BENCH_suggest.json"))
    ap.add_argument("--out", default=None,
                    help="where to write fresh rows (default: temp only, "
                         "or the baseline itself with --update)")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="fail when a gated row exceeds this multiple of "
                         "its baseline (default 3.0 — the gate catches "
                         "order-of-magnitude regressions; run on an idle "
                         "machine, 2x noise under CPU contention is real)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline with the fresh rows")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO))
    sys.path.insert(0, str(REPO / "src"))
    from benchmarks import run as bench_run

    fresh = bench_run.collect(quick=True)
    out = args.out or (args.baseline if args.update else None)
    if out:
        # merge into an existing baseline: the quick sweep covers only a
        # subset of rows (no h150 etc.) and must not drop the rest — and
        # keep run.py's schema (created timestamp, quick flag) intact
        payload = {"schema": 1, "unit": "us", "quick": True, "rows": {}}
        if pathlib.Path(out).exists():
            try:
                prior = json.loads(pathlib.Path(out).read_text())
                if isinstance(prior.get("rows"), dict):
                    payload.update(prior)
            except json.JSONDecodeError:
                pass
        payload["rows"] = dict(payload["rows"], **fresh)
        payload["created"] = time.time()
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {out} ({len(fresh)} refreshed, "
              f"{len(payload['rows'])} total rows)")
    if args.update:
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"no baseline at {base_path}; run with --update to create one")
        return 0
    baseline = json.loads(base_path.read_text())["rows"]

    failures = []
    for name, us in sorted(fresh.items()):
        ref = baseline.get(name)
        gated = (any(name.startswith(p) for p in GATED_PREFIXES)
                 and name not in UNGATED_ROWS)
        note = ""
        if ref:
            ratio = us / ref
            note = f"  baseline={ref:.0f}us  x{ratio:.2f}"
            if gated and ratio > args.tolerance:
                note += "  REGRESSION"
                failures.append(name)
        else:
            # not yet in the committed baseline (e.g. a freshly added
            # contention row): reported, never gated — run --update to
            # start tracking it
            note = "  (new; no baseline)"
        print(f"{name:44s} {us:10.0f}us{note}")
    if failures:
        print(f"\nPERF GATE FAILED ({len(failures)} rows > "
              f"{args.tolerance}x baseline): {', '.join(failures)}")
        return 1
    print("\nperf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
