"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: one forward/train step on CPU asserting output shapes
and no NaNs, plus prefill+decode == teacher-forced forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.registry import concrete_inputs
from repro.models import LM
from repro.models.common import SHAPES, ShapeSpec, shape_applicable

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_inputs(cfg, ShapeSpec("t", 32, 2, "train"))
    logits, aux = jax.jit(model.forward)(params, batch)
    n_text = batch["tokens"].shape[1]
    assert logits.shape == (2, n_text, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(capacity_factor=64.0)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    S = 24 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    B, PROMPT = 2, 10
    batch = concrete_inputs(cfg, ShapeSpec("t", S, B, "train"), seed=1)
    full, _ = jax.jit(model.forward)(params, batch)
    pb = {k: (v[:, :PROMPT] if k == "tokens" else v)
          for k, v in batch.items() if k != "labels"}
    cache, pl_logits = jax.jit(
        lambda p, b: model.prefill(p, b, S))(params, pb)
    errs = [float(jnp.max(jnp.abs(pl_logits - full[:, PROMPT - 1])))]
    dstep = jax.jit(model.decode_step)
    for t in range(PROMPT, PROMPT + 4):
        lg, cache = dstep(params, cache, batch["tokens"][:, t])
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-4, errs


def test_param_counts_match_assignment():
    """Full configs land near their advertised sizes (6ND inputs)."""
    expected = {"phi3-medium-14b": 14.0e9, "command-r-plus-104b": 104e9,
                "granite-3-8b": 8.2e9, "granite-8b": 8.1e9,
                "llava-next-34b": 34e9, "deepseek-v2-lite-16b": 15.7e9,
                "granite-moe-3b-a800m": 3.3e9, "recurrentgemma-2b": 2.7e9,
                "whisper-medium": 0.76e9, "xlstm-125m": 0.16e9}
    for arch, target in expected.items():
        n = get_config(arch).param_count()
        assert 0.75 * target < n < 1.35 * target, (arch, n, target)


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCHS
                if shape_applicable(get_config(a), long)[0]}
    assert runnable == {"xlstm-125m", "recurrentgemma-2b"}
