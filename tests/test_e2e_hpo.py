"""Integration: HPO over an actual trainable LM + resume-from-store."""
import tempfile

import numpy as np
import pytest

from repro.core import (ExperimentConfig, Observation, Orchestrator, Param,
                        Resources, Space)
from repro.launch.train import train


def lm_trial(a, ctx):
    loss = train("xlstm-125m", steps=12, batch=2, seq=32, reduced=True,
                 lr=a["lr"], warmup=2, log=ctx.log, log_every=6,
                 seed=int(a.get("seed", 0)))
    return loss


@pytest.mark.slow
def test_hpo_finds_reasonable_lr():
    orch = Orchestrator(tempfile.mkdtemp())
    cfg = ExperimentConfig(
        name="lm-lr", budget=6, parallel=2, optimizer="sobol", goal="min",
        space=Space([Param("lr", "double", 1e-5, 3e-1, log=True)]))
    exp = orch.run(cfg, trial_fn=lm_trial)
    st = orch.status(exp)
    assert st["observations"] == 6
    assert st["best"]["value"] is not None


def test_resume_replays_observations():
    orch = Orchestrator(tempfile.mkdtemp())
    space = Space([Param("x", "double", 0, 1)])
    cfg = ExperimentConfig(name="resume", budget=4, parallel=2,
                           optimizer="gp", space=space)
    exp = orch.run(cfg, trial_fn=lambda a, ctx: -(a["x"] - 0.4) ** 2)
    # resume with a bigger budget: optimizer must start warm
    cfg2 = ExperimentConfig(name="resume", budget=8, parallel=2,
                            optimizer="gp", space=space)
    orch2 = Orchestrator(str(orch.store.root))
    exp2 = orch2.run(cfg2, trial_fn=lambda a, ctx: -(a["x"] - 0.4) ** 2,
                     exp_id=exp)
    assert exp2 == exp
    obs = orch2.store.load_observations(exp)
    assert len(obs) == 8
