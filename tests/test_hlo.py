"""HLO analyzer: trip-count scaling, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.hlo import analyze
from repro.distributed.auto_shard import auto_spec, batch_seq_spec
from jax.sharding import PartitionSpec as P


def test_scan_trip_count_scaling():
    def body(x, w):
        return x @ w, ()

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    a = analyze(c.as_text(), 1)
    assert a["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, ()

    def outer(x, ws):
        def body(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, ()
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    a = analyze(c.as_text(), 1)
    assert a["flops"] == pytest.approx(4 * 5 * 2 * 64 ** 3, rel=0.01)


def test_matmul_flops_unscanned():
    f = lambda a, b: a @ b
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 128), jnp.float32),
                         jax.ShapeDtypeStruct((128, 64), jnp.float32)
                         ).compile()
    a = analyze(c.as_text(), 1)
    assert a["flops"] == pytest.approx(2 * 256 * 128 * 64, rel=0.01)


# --- sharding rule helpers -------------------------------------------------
class _FakeMesh:
    def __init__(self, axes):
        self.shape = dict(axes)
        self.axis_names = tuple(self.shape)


def test_auto_spec_divisibility():
    mesh = _FakeMesh([("data", 16), ("model", 16)])
    # 40 heads divide neither axis; d dims divide both
    spec = auto_spec((40, 5120, 17920), mesh, min_elems=0)
    assert spec[0] is None
    used = []
    for s in spec[1:]:
        if isinstance(s, str):
            used.append(s)
        elif s:
            used.extend(s)
    assert set(used) == {"data", "model"}


def test_auto_spec_small_leaf_replicated():
    mesh = _FakeMesh([("data", 16), ("model", 16)])
    assert auto_spec((4, 4, 192, 192), mesh) == P(None, None, None, None)


def test_batch_seq_spec_full_batch_shard():
    mesh = _FakeMesh([("data", 16), ("model", 16)])
    assert batch_seq_spec(mesh, 256, 4096) == P(("data", "model"), None)


def test_batch_seq_spec_sequence_parallel_fallback():
    mesh = _FakeMesh([("data", 16), ("model", 16)])
    assert batch_seq_spec(mesh, 32, 32768) == P(("data",), ("model",))


def test_batch_seq_spec_multipod():
    mesh = _FakeMesh([("pod", 2), ("data", 16), ("model", 16)])
    assert batch_seq_spec(mesh, 256, 4096) == P(("pod", "data"), ("model",))
