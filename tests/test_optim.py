"""AdamW vs a straight-line numpy reference; clipping; schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update, global_norm,
                         linear_warmup_cosine)


def _np_adamw(p, g, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    p = p - lr * (mh / (np.sqrt(vh) + eps) + wd * p)
    return p, m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, clip_norm=0.0, weight_decay=0.1)
    rng = np.random.default_rng(0)
    p0 = rng.normal(0, 1, (6,)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    opt = adamw_init(params)
    p_np, m_np, v_np = p0.copy(), np.zeros(6), np.zeros(6)
    for t in range(1, 6):
        g = rng.normal(0, 1, (6,)).astype(np.float32)
        params, opt, _ = adamw_update({"w": jnp.asarray(g)}, opt, params, cfg)
        p_np, m_np, v_np = _np_adamw(p_np, g, m_np, v_np, t,
                                     cfg.lr, cfg.b1, cfg.b2, cfg.eps,
                                     cfg.weight_decay)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np,
                                   rtol=2e-5, atol=2e-6)


def test_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(g, opt, params, cfg)
    assert float(metrics["grad_norm"]) == 200.0     # reported pre-clip


def test_schedule_warmup_then_decay():
    fn = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert float(fn(60)) < 1.0
    assert float(fn(109)) >= 0.1 - 1e-6             # final_frac floor


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
