"""Fault-injection coverage: every ``wrap_trial`` branch under the
scheduler's retry policy, and crash-vs-pending-suggestion hygiene (an
injected crash mid-report must not orphan a pending suggestion — the
service either counts it as a failed observation or reclaims the budget
via release/forget)."""
import tempfile

import numpy as np
import pytest

from repro.core import (ExperimentConfig, Orchestrator, Param, Space)
from repro.core.faults import FaultPolicy, InjectedCrash, wrap_trial


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg(**kw):
    kw.setdefault("optimizer", "random")
    kw.setdefault("space", _space())
    return ExperimentConfig(**kw)


def _orch():
    orch = Orchestrator(tempfile.mkdtemp())
    return orch, orch.client   # default client is a LocalClient sharing
                               # the orchestrator's Store instance


# --------------------------------------------------------- wrap_trial paths
def test_wrap_trial_crash_branch_respects_retry_policy():
    orch, _ = _orch()
    attempts = {}

    def trial(a, ctx):
        attempts[round(a["x"], 6)] = attempts.get(round(a["x"], 6), 0) + 1
        return a["x"]

    wrapped = wrap_trial(trial, FaultPolicy(p_crash=1.0, seed=1))
    exp = orch.run(_cfg(name="crash", budget=3, parallel=2, max_retries=2),
                   trial_fn=wrapped)
    obs = orch.store.load_observations(exp)
    assert len(obs) == 3 and all(o.failed for o in obs)
    # p_crash=1.0 crashes BEFORE the user fn: the inner trial never runs,
    # but each spec was retried to the cap (attempt goes 0,1,2)
    assert attempts == {}
    assert orch.status(exp)["failures"] == 3


def test_wrap_trial_nan_branch_is_not_a_failure():
    orch, _ = _orch()
    wrapped = wrap_trial(lambda a, ctx: a["x"],
                         FaultPolicy(p_nan=1.0, seed=2))
    exp = orch.run(_cfg(name="nan", budget=4, parallel=2, max_retries=0),
                   trial_fn=wrapped)
    obs = orch.store.load_observations(exp)
    assert len(obs) == 4
    # a diverged model returns NaN: recorded as data, not as a crash
    assert all(not o.failed and np.isnan(o.value) for o in obs)


def test_wrap_trial_straggler_branch_slows_but_completes():
    orch, _ = _orch()
    seen = []

    def trial(a, ctx):
        seen.append(a["x"])
        return a["x"]

    wrapped = wrap_trial(trial, FaultPolicy(p_slow=1.0, slow_factor=1.5,
                                            seed=3))
    exp = orch.run(_cfg(name="slow", budget=3, parallel=3, max_retries=0),
                   trial_fn=wrapped)
    obs = orch.store.load_observations(exp)
    assert len(obs) == 3 and len(seen) == 3
    assert all(not o.failed for o in obs)
    logs = list(orch.store.iter_logs(exp))
    assert any("fault-injection: straggler" in ln for ln in logs)


def test_wrap_trial_mixed_policy_under_retries():
    orch, _ = _orch()
    wrapped = wrap_trial(lambda a, ctx: a["x"],
                         FaultPolicy(p_crash=0.4, p_nan=0.2, seed=5))
    exp = orch.run(_cfg(name="mix", budget=16, parallel=4, max_retries=1),
                   trial_fn=wrapped)
    obs = orch.store.load_observations(exp)
    assert len(obs) == 16
    crashed = [o for o in obs if o.failed]
    # (wrap_trial rolls are keyed on the per-process string hash, so the
    # crash/nan split varies by run — only the dominant class is asserted)
    assert crashed, "some crashes expected at p_crash=0.4 over 16 trials"
    # deterministic injection within a process: a crashed assignment
    # crashes on retry too — failures burn max_retries+1 attempts and the
    # budget still completes exactly
    assert orch.status(exp)["observations"] == 16


# -------------------------------------------- pending hygiene across crashes
def test_crash_mid_report_leaves_no_orphaned_pending():
    """A trial that crashes AFTER streaming progress reports must not leak
    its pending suggestion: the failed observe closes it, and the GP's
    constant-liar lie for the point is retired (Optimizer.forget /
    tell-with-__lie-key)."""
    orch, client = _orch()

    def trial(a, ctx):
        ctx.report(1, a["x"])
        raise InjectedCrash("mid-report crash")

    cfg = _cfg(name="midreport", budget=5, parallel=2, max_retries=0,
               optimizer="gp",
               optimizer_options={"n_init": 2, "fit_steps": 20},
               early_stop={"min_steps": 1, "eta": 2})
    exp = orch.run(cfg, trial_fn=trial)
    state = client._exps[exp]
    assert state.pending == {}, "crashed trials must not hold pending"
    assert not getattr(state.optimizer, "_pending", {}), \
        "constant-liar lies must be retired when the point resolves"
    obs = orch.store.load_observations(exp)
    # every budget slot resolved: either the crash (failed) or a
    # service-side prune that beat the crash to the report (partial value)
    assert len(obs) == 5
    assert all(o.failed or o.metadata.get("pruned") for o in obs)
    assert any(o.failed for o in obs), "some crashes expected"
    # the metric stream up to the crash IS persisted (partial curves
    # survive for post-mortems / future multi-fidelity optimizers)
    assert client.store.load_metrics(exp), "pre-crash reports persisted"


def test_delete_mid_run_releases_and_forgets_pending():
    """The other reclaim path: a crash storm followed by delete — every
    locally-requeued spec is released and its lie forgotten."""
    import threading
    orch, client = _orch()
    started = threading.Event()

    def trial(a, ctx):
        started.set()
        ctx.report(1, a["x"])
        raise InjectedCrash("boom")

    cfg = _cfg(name="reclaim", budget=30, parallel=2, max_retries=5,
               optimizer="gp",
               optimizer_options={"n_init": 2, "fit_steps": 20})
    exp = orch.run(cfg, trial_fn=trial, background=True)
    assert started.wait(10.0)
    orch.delete(exp)
    orch.wait(exp, timeout=20)
    state = client._exps[exp]
    assert state.pending == {}
    assert not getattr(state.optimizer, "_pending", {})
