"""Store persistence + the six CLI verbs (paper §3.1)."""
import json
import pathlib
import tempfile

import pytest
import yaml

from repro.core import ExperimentConfig, Observation, Param, Space, Store
from repro.launch.cli import main as cli_main


def test_store_observation_log_roundtrip():
    store = Store(tempfile.mkdtemp())
    cfg = ExperimentConfig(name="x", space=Space([Param("a", "double", 0, 1)]))
    store.create_experiment("e1", cfg)
    store.append_observation("e1", Observation({"a": 0.5}, 1.0), "t1")
    store.append_observation("e1", Observation({"a": 0.1}, None, failed=True),
                             "t2")
    obs = store.load_observations("e1")
    assert len(obs) == 2 and obs[1].failed
    cfg2 = store.load_config("e1")
    assert cfg2.name == "x" and cfg2.space.names == ["a"]


def test_logs_aggregated_per_experiment():
    store = Store(tempfile.mkdtemp())
    cfg = ExperimentConfig(name="x", space=Space([Param("a", "double", 0, 1)]))
    store.create_experiment("e1", cfg)
    store.append_log("e1", "t1", "hello from t1")
    store.append_log("e1", "t2", "hello from t2")
    lines = list(store.iter_logs("e1"))
    assert "[t1] hello from t1" in lines and "[t2] hello from t2" in lines


# --- CLI ------------------------------------------------------------------
def objective(assignment, ctx):
    ctx.log(f"x={assignment['x']}")
    return -(assignment["x"] - 0.25) ** 2


def test_cli_full_lifecycle(tmp_path, capsys):
    store = str(tmp_path / "store")
    cluster_yml = tmp_path / "cluster.yml"
    cluster_yml.write_text(yaml.safe_dump({
        "cluster_name": "orchestrate-cluster",
        "cloud_provider": "local",
        "pools": [{"name": "tpu", "resource": "tpu", "chips": 8}],
    }))
    exp_yml = tmp_path / "exp.yml"
    exp_yml.write_text(yaml.safe_dump({
        "name": "cli-exp", "budget": 6, "parallel": 3,
        "optimizer": "random",
        "space": [{"name": "x", "type": "double", "bounds": [0, 1]}],
        "resources": {"pool": "tpu", "chips": 2},
        "entrypoint": "tests.test_store_cli:objective",
    }))
    assert cli_main(["--store", store, "cluster", "create",
                     "-f", str(cluster_yml)]) == 0
    assert cli_main(["--store", store, "run", "-f", str(exp_yml)]) == 0
    out = capsys.readouterr().out
    assert "6 / 6 Observations" in out

    exp_id = sorted((pathlib.Path(store) / "experiments").iterdir())[-1].name
    assert cli_main(["--store", store, "status", exp_id]) == 0
    assert "Observations" in capsys.readouterr().out
    assert cli_main(["--store", store, "logs", exp_id]) == 0
    assert "x=" in capsys.readouterr().out
    assert cli_main(["--store", store, "delete", exp_id]) == 0
    # destroying the cluster keeps experiment records (paper §2.6)
    assert cli_main(["--store", store, "cluster", "destroy",
                     "-n", "orchestrate-cluster"]) == 0
    assert (pathlib.Path(store) / "experiments" / exp_id /
            "observations.jsonl").exists()
