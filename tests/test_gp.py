"""GP regression correctness."""
import numpy as np
import pytest

from repro.core.suggest import gp


def test_posterior_interpolates_training_points():
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(24, 2))
    y = np.sin(4 * x[:, 0]) + 0.5 * x[:, 1]
    post = gp.fit_gp(x, y, steps=220)
    mu, sd = gp.predict(post, x.astype(np.float32))
    assert float(np.max(np.abs(np.asarray(mu) - y))) < 0.12
    assert float(np.mean(sd)) < 0.35


def test_posterior_uncertainty_grows_off_data():
    rng = np.random.default_rng(1)
    x = rng.uniform(0.0, 0.4, size=(16, 1))
    y = np.sin(6 * x[:, 0])
    post = gp.fit_gp(x, y, steps=200)
    _, sd_near = gp.predict(post, np.array([[0.2]], np.float32))
    _, sd_far = gp.predict(post, np.array([[0.95]], np.float32))
    assert float(sd_far[0]) > float(sd_near[0]) * 2


def test_ei_prefers_promising_region():
    x = np.array([[0.1], [0.5], [0.9]])
    y = np.array([0.0, 1.0, 0.0])
    post = gp.fit_gp(x, y, steps=200)
    q = np.array([[0.5], [0.05]], np.float32)
    ei = np.asarray(gp.expected_improvement(post, q, np.float32(1.0)))
    assert np.all(ei >= 0)
