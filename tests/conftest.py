import pathlib
import sys

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "contention: multi-client service stress test (skipped "
        "unless REPRO_CONTENTION=1; run by scripts/ci.sh tier-2)")
    config.addinivalue_line(
        "markers", "chaos: deterministic fault-injection fleet test "
        "(skipped unless REPRO_CHAOS=1; run by scripts/ci.sh tier-2)")
