"""ISSUE 5 contracts: the shared FitExecutor (priority, coalescing,
lock-free fit phase), the adaptive refit budget, the sparse speculative
posterior (exact-parity and staleness containment), and bounded hyperfit
debt under sustained suggest/observe load."""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.api import CreateExperiment, LocalClient, ObserveRequest
from repro.api.pipeline import (FitExecutor, PRIO_IDLE, PRIO_MISS,
                                PRIO_REFILL, fit_executor)
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space, strip_internal
from repro.core.suggest import Observation, gp, make_optimizer
from repro.core.suggest.bayesopt import (ADAPT_N, FIT_DUTY,
                                         MAX_REFIT_EVERY, MIN_WARM_STEPS)


def _space():
    return Space([Param("x", "double", 0, 1),
                  Param("y", "double", 1e-4, 1e0, log=True)])


def _f(a):
    return -((a["x"] - 0.62) ** 2 + (np.log10(a["y"]) + 2.0) ** 2)


def _seeded_gp(n, seed=0, **kw):
    """A GP with an n-point seeded history and fitted hyperparameters."""
    opt = make_optimizer("gp", _space(), seed=seed, n_init=4,
                         fit_steps=30, warm_fit_steps=10, **kw)
    rng = np.random.default_rng(seed)
    obs = [Observation(a, _f(a)) for a in opt.space.sample(rng, n)]
    opt.tell(obs)
    assert opt.maintain()       # the (cold) hyperparameter fit, no lies
    return opt


def _wait(predicate, timeout=10.0, every=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(every)
    return predicate()


# ------------------------------------------------------------ FitExecutor
def test_executor_runs_jobs_in_priority_order():
    ex = FitExecutor(workers=1)
    try:
        order = []
        gate = threading.Event()
        # occupy the single worker so later submits queue up
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_IDLE)
        _wait(lambda: ex.backlog() == 0)        # picked up
        ex.submit("idle", lambda: (order.append("idle"), False)[-1],
                  PRIO_IDLE)
        ex.submit("refill", lambda: (order.append("refill"), False)[-1],
                  PRIO_REFILL)
        ex.submit("miss", lambda: (order.append("miss"), False)[-1],
                  PRIO_MISS)
        gate.set()
        assert _wait(lambda: len(order) == 3)
        assert order == ["miss", "refill", "idle"]
    finally:
        ex.stop()


def test_executor_coalesces_per_key_and_escalates():
    ex = FitExecutor(workers=1)
    try:
        ran = []
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_IDLE)
        _wait(lambda: ex.backlog() == 0)
        ex.submit("exp1", lambda: (ran.append("v1"), False)[-1], PRIO_IDLE)
        # re-submit same key: one outstanding job, freshest fn, best prio
        ex.submit("exp1", lambda: (ran.append("v2"), False)[-1], PRIO_MISS)
        assert ex.backlog() == 1
        gate.set()
        assert _wait(lambda: len(ran) == 1)
        time.sleep(0.1)         # a duplicate would land right after
        assert ran == ["v2"]
        assert ex.stats["coalesced"] == 1
    finally:
        ex.stop()


def test_executor_requeues_and_cancels():
    ex = FitExecutor(workers=1)
    try:
        tries = []
        ex.submit("retry", lambda: (tries.append(1), len(tries) < 3)[-1])
        assert _wait(lambda: len(tries) == 3)
        time.sleep(0.1)
        assert len(tries) == 3 and ex.stats["requeued"] == 2
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1])
        _wait(lambda: ex.backlog() == 0)
        ran = []
        ex.submit("doomed", lambda: (ran.append(1), False)[-1])
        assert ex.cancel("doomed") and ex.backlog() == 0
        gate.set()
        time.sleep(0.1)
        assert ran == []
    finally:
        ex.stop()


def test_executor_survives_job_exceptions():
    ex = FitExecutor(workers=1)
    try:
        def boom():
            raise RuntimeError("job died")
        ex.submit("bad", boom)
        ok = []
        ex.submit("good", lambda: (ok.append(1), False)[-1])
        assert _wait(lambda: ok == [1])
        assert ex.alive
        # a failing fit is not silent: it is surfaced in the snapshot
        snap = ex.snapshot()
        assert snap["failed"] == 1
        assert "RuntimeError: job died" in snap["last_error"]
    finally:
        ex.stop()


def test_fit_executor_singleton_revives_after_stop():
    ex = fit_executor()
    assert ex.alive
    ex.stop()
    ex2 = fit_executor()
    assert ex2.alive and ex2 is not ex


# --------------------------------------------------- adaptive refit budget
def test_schedule_keeps_base_constants_for_small_histories():
    opt = make_optimizer("gp", _space(), warm_fit_steps=40, refit_every=4)
    rng = np.random.default_rng(0)
    opt.tell([Observation(a, _f(a)) for a in opt.space.sample(rng, ADAPT_N)])
    assert opt.warm_steps() == 40
    assert opt.refit_period() == 4


def test_warm_steps_halve_on_a_prewarmed_ladder():
    """The adaptive step budget shrinks with history but only through
    discrete halvings (a smooth 1/n would recompile ``_fit`` per size),
    and never below MIN_WARM_STEPS."""
    opt = make_optimizer("gp", _space(), warm_fit_steps=40)
    seen = set()
    for n in (10, ADAPT_N, ADAPT_N + 1, 2 * ADAPT_N, 4 * ADAPT_N,
              32 * ADAPT_N):
        s = opt._warm_steps_at(n)
        assert MIN_WARM_STEPS <= s <= 40
        seen.add(s)
    assert opt._warm_steps_at(10) == 40
    assert opt._warm_steps_at(2 * ADAPT_N) == 20
    # ladder values only: every one is a halving of the base
    assert all(40 % s == 0 for s in seen)


def test_refit_period_grows_with_history_and_fit_latency():
    opt = make_optimizer("gp", _space(), refit_every=4)
    opt._ys = [0.0] * 320
    assert opt.refit_period() == 320 // 16
    # latency pressure only applies in service-pipeline mode
    opt._fit_ema = 1.0          # 1 s fits
    opt._arrival_ema = 0.01     # 100 obs/s
    assert opt.refit_period() == 320 // 16
    opt.defer_fits = True
    expect = int(np.ceil(1.0 / (0.01 * FIT_DUTY)))
    assert opt.refit_period() == min(max(320 // 16, expect),
                                     MAX_REFIT_EVERY)
    opt._ys = [0.0] * (64 * MAX_REFIT_EVERY)
    assert opt.refit_period() == MAX_REFIT_EVERY, \
        "hyperparameter staleness must stay bounded"


def test_fit_job_two_phase_runs_compute_without_state_mutation():
    opt = _seeded_gp(24)
    opt._needs_fit = True
    job = opt.fit_job()
    assert job is not None
    params_before = opt._params
    install = job()             # the Adam loop — must not touch the GP
    assert opt._params is params_before and opt._needs_fit
    install()
    assert not opt._needs_fit and opt._needs_recondition
    assert opt._params is not params_before
    assert opt.fit_job() is None, "no debt left after install"


def test_refit_schedule_readout():
    opt = _seeded_gp(24)
    sched = opt.refit_schedule()
    assert sched["n"] == 24 and sched["fits"] == 1
    assert sched["warm_steps"] == 10 and sched["fit_ms"] > 0


# ------------------------------------------------ sparse speculative ask
def test_sparse_subset_covers_incumbent_recent_and_old():
    idx = gp.sparse_subset(500, best_idx=7)
    assert len(idx) <= gp.SPARSE_MAX
    assert 7 in idx and 499 in idx and idx.min() == 0
    # recency window: the last m//2 observations are all retained
    recent = np.arange(500 - gp.SPARSE_MAX // 2, 500)
    assert np.isin(recent, idx).all()
    # deterministic (reconditions reuse the same design)
    assert np.array_equal(idx, gp.sparse_subset(500, best_idx=7))
    assert np.array_equal(gp.sparse_subset(40, 3), np.arange(40))


@pytest.mark.parametrize("n", [32, 64])
def test_sparse_ei_argmax_matches_exact_on_small_histories(n):
    """Acceptance (ISSUE 5): on histories <= SPARSE_MAX the subset is the
    full data — the sparse EI argmax must land in the exact posterior's
    top-5 candidates."""
    rng = np.random.default_rng(1)
    x = rng.uniform(size=(n, 2))
    y = np.asarray([_f({"x": a, "y": 10 ** (b * 4 - 4)}) for a, b in x])
    exact = gp.fit_gp(x, y, steps=60)
    sparse, idx = gp.sparse_posterior(exact.params, x, y)
    assert len(idx) == n
    cand = rng.uniform(size=(256, 2)).astype(np.float32)
    best = np.float32(y.max())
    ei_exact = np.asarray(gp.expected_improvement(exact, cand, best))
    ei_sparse = np.asarray(gp.expected_improvement(sparse, cand, best))
    top5 = set(np.argsort(-ei_exact)[:5].tolist())
    assert int(np.argmax(ei_sparse)) in top5


def test_sparse_posterior_bounded_cost_for_large_histories():
    """Past SPARSE_MAX the sparse design is capped: conditioning cost is
    O(m^3) however long the history — and predictions stay sane."""
    rng = np.random.default_rng(2)
    x = rng.uniform(size=(300, 2))
    y = np.asarray([_f({"x": a, "y": 10 ** (b * 4 - 4)}) for a, b in x])
    exact = gp.fit_gp(x, y, steps=40)
    sparse, idx = gp.sparse_posterior(exact.params, x, y, extra=8)
    assert len(idx) <= gp.SPARSE_MAX
    assert sparse.capacity <= gp.bucket_size(gp.SPARSE_MAX + 8)
    mu_e, _ = map(np.asarray, gp.predict(exact, x[:16].astype(np.float32)))
    mu_s, _ = map(np.asarray, gp.predict(sparse, x[:16].astype(np.float32)))
    assert np.isfinite(mu_s).all()
    # same units: the sparse posterior predicts in raw y, close enough to
    # rank candidates (not a numerical-identity claim)
    assert np.corrcoef(mu_e, mu_s)[0, 1] > 0.5


def test_speculative_ask_uses_sparse_only_when_eligible():
    opt = _seeded_gp(80)
    # not in pipeline mode -> speculative falls through to the exact path
    pre = opt.ask(2, speculative=True)
    assert len(pre) == 2 and opt._sparse_asks == 0
    opt.defer_fits = True
    batch = opt.ask(2, speculative=True)
    assert len(batch) == 2 and opt._sparse_asks == 2
    assert 0 < opt._sparse_m <= gp.SPARSE_MAX
    # sparse lies are real pending lies: the next exact ask reconditions
    # them in, and observing retires them
    assert opt._needs_recondition
    exact = opt.ask(1)
    for a in pre + batch + exact:
        meta = {k: v for k, v in a.items() if k.startswith("__")}
        opt.tell([Observation(strip_internal(a), 0.0, metadata=meta)])
    leaked = [k for k in opt._pending]
    assert not leaked, f"leaked lies: {leaked}"


def test_small_history_never_uses_sparse():
    opt = _seeded_gp(24)
    opt.defer_fits = True
    opt.ask(2, speculative=True)
    assert opt._sparse_asks == 0, \
        "sparse path must not engage below SPARSE_MAX observations"


# -------------------------------------------- service-level integration
def _cfg(**kw):
    kw.setdefault("name", "refit")
    kw.setdefault("optimizer", "gp")
    kw.setdefault("parallel", 4)
    kw.setdefault("space", _space())
    kw.setdefault("optimizer_options", {"n_init": 2, "fit_steps": 10,
                                        "warm_fit_steps": 5})
    return ExperimentConfig(**kw)


def test_pump_never_starves_hyperfits_under_sustained_load():
    """Satellite (ISSUE 5): under a sustained suggest/observe loop the
    shared executor keeps paying the refit debt — ``_since_fit`` stays
    bounded instead of growing with the run."""
    client = LocalClient(tempfile.mkdtemp())
    exp = client.create_experiment(CreateExperiment(
        config=_cfg(budget=500, prefetch=6,
                    optimizer_options={"n_init": 2, "fit_steps": 5,
                                       "warm_fit_steps": 5,
                                       "refit_every": 4}).to_json())).exp_id
    state = client._exps[exp]
    opt = state.optimizer
    opt.prewarm(80, batch=4)    # keep XLA compiles out of the timed loop
    rng = np.random.default_rng(0)
    peak = 0
    for i in range(60):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(rng.normal())))
        peak = max(peak, opt._since_fit)
        time.sleep(0.005)
    # debt stayed bounded DURING the load (history < ADAPT_N, so the
    # period is the base refit_every=4; generous slack for fits in
    # flight + the chunk of observations a slow CI step can batch up)
    assert peak <= 4 + 3 * 8, f"refit debt grew unbounded: peak={peak}"
    assert _wait(lambda: not opt.maintenance_due(), timeout=10), \
        "owed refit never ran after load stopped"
    st = client.status(exp)
    assert st.pump["maintained"] >= 1
    assert st.pump["executor"]["executed"] >= 1
    client.stop(exp)
    client.close()


def test_sparse_queue_entries_respect_staleness_bound():
    """Acceptance (ISSUE 5): speculative entries minted from the sparse
    posterior obey the same K-observation staleness bound — a served
    suggestion is never older than K observations."""
    client = LocalClient(tempfile.mkdtemp())
    exp = client.create_experiment(CreateExperiment(
        config=_cfg(budget=400, prefetch=4, staleness=3,
                    parallel=2).to_json())).exp_id
    state = client._exps[exp]
    state.optimizer.prewarm(120, batch=4)
    rng = np.random.default_rng(0)
    # grow past SPARSE_MAX so sparse refills become eligible
    for i in range(gp.SPARSE_MAX + 8):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(rng.normal())))
    # force the saturation signal: drain the queue so suggests miss, then
    # give the pump a tick to refill — sparse engages on that refill
    deadline = time.time() + 20
    while state.stats["sparse_prefilled"] == 0 and time.time() < deadline:
        batch = client.suggest(exp, 3)
        for s in batch.suggestions:
            client.observe(ObserveRequest(exp, s.suggestion_id,
                                          s.assignment, float(rng.normal())))
        time.sleep(0.05)
    assert state.stats["sparse_prefilled"] > 0, \
        f"sparse refill never engaged: {state.stats}"
    # entries may age in the queue, but a pop re-checks: anything past
    # the K-observation bound is invalidated, never served
    for _ in range(6):
        with state.lock:
            stale = [i.assignment for i in state.queue
                     if state.observed - i.born_obs >= state.staleness]
        s = client.suggest(exp, 1).suggestions[0]
        assert s.assignment not in stale, \
            "served a sparse suggestion past its staleness bound"
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(rng.normal())))
    client.stop(exp)
    assert not state.optimizer._pending
    client.close()


def test_status_exposes_schedule_and_executor_over_http():
    from repro.api import HTTPClient, serve_api
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        http = HTTPClient(server.url)
        exp = http.create_experiment(CreateExperiment(
            config=_cfg(budget=50, prefetch=2,
                        optimizer_options={"n_init": 2, "fit_steps": 5,
                                           "warm_fit_steps": 5,
                                           "refit_every": 2}).to_json())
            ).exp_id
        st = http.status(exp)
        assert st.pump is not None
        assert "refit" in st.pump and "executor" in st.pump
        assert st.pump["refit"]["refit_every"] >= 1
        # executor stays None until a fit is actually owed (a monitoring
        # read must not spawn the worker pool); drive some observations
        # so the pump submits one
        rng = np.random.default_rng(0)
        for _ in range(8):
            s = http.suggest(exp, 1).suggestions[0]
            http.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                        float(rng.normal())))
        # 'maintained' is the honest fit signal ('executed' also counts
        # lock-race retries and no-op attempts)
        assert _wait(lambda: http.status(exp).pump.get("maintained", 0) >= 1,
                     timeout=15)
        assert http.status(exp).pump["executor"]["workers"] >= 1
    finally:
        server.shutdown()
