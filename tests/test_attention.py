"""Attention internals: chunked-causal path == dense reference, local
windows, decode chunk combine, MLA absorbed decode == naive."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.kernels import ref


def _dense_ref(q, k, v, causal=True, window=0):
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def _run_chunked(q, k, v, monkeypatch, chunk, window=0):
    monkeypatch.setattr(A, "_Q_CHUNK", chunk)
    S = q.shape[1]
    pos = jnp.arange(S)
    return A.multihead_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                 causal=True, window=window)


@pytest.mark.parametrize("S,chunk", [(300, 64), (256, 64), (129, 32)])
def test_triangular_chunked_equals_dense(S, chunk, monkeypatch):
    rng = np.random.default_rng(0)
    B, H, K, D = 2, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    out = _run_chunked(q, k, v, monkeypatch, chunk)
    want = _dense_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("window", [16, 50])
def test_banded_chunked_equals_dense(window, monkeypatch):
    rng = np.random.default_rng(1)
    B, S, H, K, D = 1, 200, 4, 1, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    out = _run_chunked(q, k, v, monkeypatch, 64, window=window)
    want = _dense_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


def test_decode_chunk_combine_matches_monolithic():
    """Sequence-sharded flash-decode: combining per-chunk stats must equal
    attention over the concatenated cache (the multi-chip decode path)."""
    rng = np.random.default_rng(2)
    B, H, K, D, S = 2, 4, 2, 16, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    pos = jnp.full((B,), S - 1)
    kv_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    scale = 1.0 / math.sqrt(D)
    whole = A.combine_decode([A.decode_attend_chunk(
        q, k, v, pos, kv_pos, scale=scale)])
    parts = [A.decode_attend_chunk(q, k[:, i:i + 16], v[:, i:i + 16], pos,
                                   kv_pos[:, i:i + 16], scale=scale)
             for i in range(0, S, 16)]
    combined = A.combine_decode(parts)
    np.testing.assert_allclose(np.asarray(combined), np.asarray(whole),
                               rtol=1e-5, atol=1e-6)


def test_ring_buffer_positions():
    """Local-attention ring cache: slot->absolute-position reconstruction."""
    pos = jnp.asarray([5, 2])
    got = A._cache_positions(pos, S=4, window=4)
    # batch 0 at pos 5: slots hold positions [4, 5, 2, 3]
    np.testing.assert_array_equal(np.asarray(got[0]), [4, 5, 2, 3])
    # batch 1 at pos 2: slot 3 not yet written
    np.testing.assert_array_equal(np.asarray(got[1]), [0, 1, 2, -1])
