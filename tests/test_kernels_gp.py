"""CPU interpret-mode parity for the Pallas kernels (ISSUE 8 bugfix).

``tests/test_kernels.py`` skips wholesale when hypothesis is absent (as
in this image), which left every ``force_kernel=True`` dispatch path —
the Pallas kernels run in interpret mode — with NO tier-1 coverage: a
kernel could drift from its jnp oracle and nothing would fail until a
TPU run.  These tests are dependency-free and cover the new GP kernels
(NLL, its analytic adjoint, EI) plus the pre-existing flash-attention /
RG-LRU / int8-quant kernels against ``kernels/ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

ATOL = 1e-5


def _gp_case(k=3, b=16, d=3, seed=0):
    """k lanes over a b-bucket with distinct masked sizes (incl. one
    nearly-empty lane) — hyperparams spread across the clamp range."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((k, b, d)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    ns = [b, max(2, b // 2), 2][:k] + [b] * max(0, k - 3)
    mask = np.zeros((k, b), np.float32)
    for i, n in enumerate(ns):
        mask[i, :n] = 1.0
    mask = jnp.asarray(mask)
    log_ls = jnp.asarray(rng.uniform(-1.5, 0.5, (k, d)), jnp.float32)
    log_amp = jnp.asarray(rng.uniform(-0.5, 0.5, (k,)), jnp.float32)
    log_noise = jnp.asarray(rng.uniform(-3.0, -1.0, (k,)), jnp.float32)
    return log_ls, log_amp, log_noise, x, y, mask


def test_gp_nll_kernel_matches_ref():
    ll, la, ln, x, y, mask = _gp_case()
    got = ops.gp_neg_mll(ll, la, ln, x, y, mask, force_kernel=True)
    want = ref.gp_nll_ref(ll, la, ln, x, y, mask)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)


def test_gp_fit_grads_kernel_matches_ref():
    """The Pallas NLL's custom_vjp (force_kernel path) against the
    GEMM-rich analytic adjoint the CPU fit loop uses — the two gradient
    implementations behind ``ops.gp_fit_grads`` must agree."""
    ll, la, ln, x, y, mask = _gp_case()
    got = ops.gp_fit_grads(ll, la, ln, x, y, mask, force_kernel=True)
    want = ops.gp_fit_grads(ll, la, ln, x, y, mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-2, rtol=1e-3)


def test_gp_grads_ref_matches_autodiff():
    """The analytic adjoint against autodiff of the NLL oracle itself —
    pins the hand-derived Matérn-5/2 derivative formulas."""
    ll, la, ln, x, y, mask = _gp_case(seed=1)

    def nll_sum(a, b_, c):
        return jnp.sum(ref.gp_nll_ref(a, b_, c, x, y, mask))

    want = jax.grad(nll_sum, argnums=(0, 1, 2))(ll, la, ln)
    got = ref.gp_nll_grads_ref(ll, la, ln, x, y, mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-2, rtol=1e-3)


def test_gp_grads_inert_lane_is_zero():
    """All-zero-mask lanes (batch padding) must contribute exactly zero
    gradient — anything else would let padding perturb real lanes'
    Adam state in ``gp._fit_lanes``."""
    ll, la, ln, x, y, mask = _gp_case()
    mask = mask.at[1].set(0.0)
    g_ll, g_la, g_ln = ref.gp_nll_grads_ref(ll, la, ln, x, y, mask)
    assert float(jnp.max(jnp.abs(g_ll[1]))) == 0.0
    assert float(g_la[1]) == 0.0
    assert float(g_ln[1]) == 0.0


def test_gp_ei_kernel_matches_ref():
    ll, la, ln, x, y, mask = _gp_case()
    k, b, d = x.shape
    rng = np.random.default_rng(2)
    # build each lane's posterior factors the way the optimizer does
    noise2 = jnp.exp(2.0 * ln) + 1e-5
    mm = mask[:, :, None] * mask[:, None, :]
    eye = jnp.eye(b, dtype=x.dtype)
    mat = jax.vmap(ref._matern52)(x, x, ll, la)
    cov = (mat + noise2[:, None, None] * eye) * mm \
        + (1.0 - mask)[:, :, None] * eye
    chol = jnp.linalg.cholesky(cov)
    ym = y * mask
    alpha = jax.vmap(lambda L, v: jax.scipy.linalg.cho_solve((L, True), v))(
        chol, ym)
    y_mean = jnp.asarray(rng.standard_normal(k), jnp.float32)
    y_std = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
    cand = jnp.asarray(rng.random((k, 8, d)), jnp.float32)
    best = jnp.asarray(rng.standard_normal(k), jnp.float32)
    got = ops.gp_ei(ll, la, x, mask, chol, alpha, y_mean, y_std, cand,
                    best, force_kernel=True)
    want = ref.gp_ei_ref(ll, la, x, mask, chol, alpha, y_mean, y_std,
                         cand, best)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)


def test_flash_attention_kernel_matches_ref():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 16, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 2, 8)), jnp.float32)
    for kw in ({"causal": True}, {"causal": True, "window": 4},
               {"causal": False, "softcap": 5.0}):
        got = ops.flash_attention(q, k, v, force_kernel=True, **kw)
        want = ref.flash_attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_rglru_scan_kernel_matches_ref():
    rng = np.random.default_rng(4)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((2, 32, 8))),
                        jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    got = ops.rglru_scan(log_a, b, force_kernel=True)
    want = ref.rglru_scan_ref(log_a, b)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_int8_quantize_kernel_matches_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q_got, s_got = ops.int8_quantize(x, force_kernel=True)
    q_want, s_want = ref.int8_quant_ref(x)
    np.testing.assert_allclose(s_got, s_want, atol=1e-7, rtol=1e-6)
    assert int(jnp.max(jnp.abs(q_got.astype(jnp.int32)
                               - q_want.astype(jnp.int32)))) <= 1
