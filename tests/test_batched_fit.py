"""ISSUE 8 contracts: cross-experiment fit batching.

The FitExecutor coalesces queued batchable fits sharing a
(runner, bucket, steps) group into ONE vmap'd dispatch
(``gp.batched_fit``); this file pins the equivalence (batched params ==
serial params), the compile discipline (one XLA compile per lane-pad,
zero on re-dispatch), the grouping rule (mixed buckets never co-batch),
the PRIO_MISS latency contract (urgent fits skip the gather window) and
— under REPRO_CONTENTION — k=16 real concurrent refits through the
executor."""
import os
import threading
import time

import numpy as np
import pytest

from repro.api import pipeline
from repro.api.pipeline import (BatchableFit, FitExecutor, FitLane,
                                PRIO_IDLE, PRIO_MISS, RETRY)
from repro.core.space import Param, Space
from repro.core.suggest import Observation, gp, make_optimizer


def _space():
    return Space([Param("x", "double", 0, 1),
                  Param("y", "double", 1e-4, 1e0, log=True)])


def _f(a):
    return -((a["x"] - 0.62) ** 2 + (np.log10(a["y"]) + 2.0) ** 2)


def _wait(predicate, timeout=10.0, every=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(every)
    return predicate()


def _experiments(k, n=20, d=4, seed=0):
    rng = np.random.default_rng(seed)
    items = []
    for i in range(k):
        x = rng.random((n, d))
        w = rng.random(d)
        y = np.sin(3.0 * x @ w) + 0.1 * rng.standard_normal(n)
        items.append((x, y, None))
    return items


# ------------------------------------------------------- gp.batched_fit
def test_batched_fit_matches_serial_fits():
    """k lanes through one vmap'd dispatch must land on the same
    hyperparameters as k independent fit_gp calls (same steps, same
    warm start) — lanes are independent by construction."""
    items = _experiments(5)
    batched = gp.batched_fit(items, steps=25, bucket=32)
    for (x, y, p0), bp in zip(items, batched):
        post = gp.fit_gp(x, y, steps=25, params0=p0, bucket=32)
        np.testing.assert_allclose(bp.log_ls, post.params.log_ls,
                                   atol=1e-4)
        np.testing.assert_allclose(bp.log_amp, post.params.log_amp,
                                   atol=1e-4)
        np.testing.assert_allclose(bp.log_noise, post.params.log_noise,
                                   atol=1e-4)


def test_batched_fit_one_dispatch_one_compile():
    """One (bucket, steps, lane-pad) triple costs exactly one XLA
    compile; re-dispatch at any k within the same lane-pad reuses it."""
    items = _experiments(6, seed=3)
    before = gp._fit_lanes._cache_size()
    gp.batched_fit(items[:5], steps=12, bucket=32)      # lane_pad(5) == 8
    mid = gp._fit_lanes._cache_size()
    assert mid == before + 1
    gp.batched_fit(items[:6], steps=12, bucket=32)      # lane_pad(6) == 8
    assert gp._fit_lanes._cache_size() == mid


# --------------------------------------------------- executor co-batching
class _Spec:
    __slots__ = ("bucket", "steps", "runner", "install")

    def __init__(self, bucket, steps, runner):
        self.bucket, self.steps, self.runner = bucket, steps, runner


def _recording_runner(calls):
    def runner(specs):
        calls.append([s.bucket for s in specs])
        return [None] * len(specs), 0.001
    return runner


def test_executor_cobatches_same_group_only():
    """Queued batchable fits sharing (runner, bucket, steps) dispatch
    together; a different bucket must run in its own dispatch."""
    calls, installed = [], []
    runner = _recording_runner(calls)

    def make(bucket):
        spec = _Spec(bucket, 40, runner)
        return BatchableFit(lambda: FitLane(
            spec, lambda p, dt: installed.append(bucket)))

    ex = FitExecutor(workers=1)
    try:
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i in range(4):
            ex.submit(f"e{i}", make(64), PRIO_IDLE)
        ex.submit("odd", make(128), PRIO_IDLE)
        gate.set()
        assert _wait(lambda: len(installed) == 5)
        assert sorted(len(c) for c in calls) == [1, 4]
        assert [64] * 4 in calls and [128] in calls
        snap = ex.snapshot()
        assert snap["lanes"] == 5 and snap["batched"] == 2
        assert snap["mean_batch"] == pytest.approx(2.5)
    finally:
        ex.stop()


def test_executor_caps_batch_at_max_lanes():
    calls, installed = [], []
    runner = _recording_runner(calls)
    ex = FitExecutor(workers=1)
    ex.MAX_LANES = 4        # pin the (normally dynamic) cap
    try:
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i in range(ex.MAX_LANES + 3):
            spec = _Spec(64, 40, runner)
            ex.submit(f"e{i}", BatchableFit(
                lambda s=spec: FitLane(
                    s, lambda p, dt: installed.append(1))), PRIO_IDLE)
        gate.set()
        assert _wait(lambda: len(installed) == ex.MAX_LANES + 3)
        assert max(len(c) for c in calls) == ex.MAX_LANES
    finally:
        ex.stop()


def test_executor_max_lanes_scales_with_backlog():
    """The dynamic cap tracks backlog per worker: idle -> LANES_MIN, a
    deep queue -> more lanes (power of two), never past LANES_CAP."""
    ex = FitExecutor(workers=1)
    try:
        assert ex.max_lanes() == ex.LANES_MIN
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i in range(6):
            ex.submit(f"e{i}", lambda: False, PRIO_IDLE)
        lanes = ex.max_lanes()
        assert lanes == 8                   # 6 queued / 1 worker -> pad to 8
        assert ex.snapshot()["max_lanes"] == lanes
        for i in range(40):
            ex.submit(f"x{i}", lambda: False, PRIO_IDLE)
        assert ex.max_lanes() == ex.LANES_CAP
        gate.set()
    finally:
        ex.stop()


def test_retry_snapshot_requeues_until_lane_appears():
    seen = []
    spec = _Spec(64, 40, _recording_runner([]))

    def snap():
        seen.append(1)
        if len(seen) < 3:
            return RETRY
        return FitLane(spec, lambda p, dt: seen.append("installed"))

    ex = FitExecutor(workers=1)
    try:
        ex.submit("r", BatchableFit(snap), PRIO_IDLE)
        assert _wait(lambda: "installed" in seen)
        assert ex.snapshot()["requeued"] >= 2
    finally:
        ex.stop()


def test_prio_miss_skips_gather_window():
    """A miss-urgent fit must dispatch immediately — the gather window
    is only for fits no request is waiting on.  Pin it by making the
    window pathologically long: the PRIO_MISS fit still installs fast,
    and an idle fit on the same executor waits the window out."""
    ex = FitExecutor(workers=1)
    ex.GATHER_WINDOW = 1.5
    try:
        done = []
        spec = _Spec(64, 40, _recording_runner([]))

        def submit(key, prio):
            t0 = time.monotonic()
            ex.submit(key, BatchableFit(lambda: FitLane(
                spec, lambda p, dt: done.append(
                    (key, time.monotonic() - t0)))), prio)

        submit("miss", PRIO_MISS)
        assert _wait(lambda: len(done) == 1, timeout=1.0)
        assert done[0][1] < 1.0     # never slept the 1.5s window
        submit("idle", PRIO_IDLE)
        assert _wait(lambda: len(done) == 2, timeout=10.0)
        assert done[1][1] >= ex.GATHER_WINDOW
    finally:
        ex.stop()


# ------------------------------------------------ contended real refits
@pytest.mark.contention
@pytest.mark.skipif(not os.environ.get("REPRO_CONTENTION"),
                    reason="set REPRO_CONTENTION=1 (ci.sh tier-2)")
def test_sixteen_concurrent_refits_cobatch_through_executor():
    """16 real GP optimizers owing warm refits, pushed through one
    1-worker executor as batchable lanes: all must install, and the
    executor must have amortized them into multi-lane dispatches."""
    opts, locks = [], []
    for i in range(16):
        opt = make_optimizer("gp", _space(), seed=i, n_init=4,
                             fit_steps=30, warm_fit_steps=10)
        rng = np.random.default_rng(i)
        opt.tell([Observation(a, _f(a))
                  for a in opt.space.sample(rng, 24)])
        assert opt.maintenance_due()
        opts.append(opt)
        locks.append(threading.Lock())

    installed = []

    def make_snapshot(opt, lock):
        def snap():
            if not lock.acquire(timeout=0.05):
                return RETRY
            try:
                spec = opt.fit_spec()
            finally:
                lock.release()
            if spec is None:
                return None

            def install(params, dt):
                with lock:
                    spec.install(params, dt)
                installed.append(opt)
            return FitLane(spec, install)
        return snap

    ex = FitExecutor(workers=1)
    try:
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i, (opt, lock) in enumerate(zip(opts, locks)):
            ex.submit(f"exp{i}", BatchableFit(make_snapshot(opt, lock)),
                      PRIO_IDLE)
        gate.set()
        assert _wait(lambda: len(installed) == 16, timeout=60.0)
        snap = ex.snapshot()
        assert snap["lanes"] >= 16
        assert snap["mean_batch"] > 1.0     # real co-batching happened
        for opt in opts:
            assert opt._params is not None and opt._fits >= 1
            assert not opt.maintenance_due()
    finally:
        ex.stop()


# ----------------------------------------------------- pump integration
def test_pump_routes_gp_fits_through_batchable_path():
    """A live gp experiment's deferred refits must flow through the
    BatchableFit path (executor ``lanes`` counter moves) and still land
    as ``maintained`` installs; the quality readout carries the live
    auto-tuned ``sparse_max``."""
    import tempfile

    from repro.api import CreateExperiment, LocalClient, ObserveRequest
    from repro.core.experiment import ExperimentConfig
    from repro.core.space import strip_internal

    client = LocalClient(tempfile.mkdtemp())
    cfg = ExperimentConfig(
        name="batched-pump", space=_space(), optimizer="gp",
        budget=200, parallel=4,
        optimizer_options={"n_init": 2, "fit_steps": 10,
                           "warm_fit_steps": 5, "refit_every": 4})
    exp = client.create_experiment(
        CreateExperiment(config=cfg.to_json())).exp_id
    before = pipeline.fit_executor().snapshot()["lanes"]
    try:
        for _ in range(16):
            s = client.suggest(exp, 1).suggestions[0]
            client.observe(ObserveRequest(
                exp, s.suggestion_id, s.assignment,
                _f(strip_internal(s.assignment))))
            time.sleep(0.005)
        assert _wait(
            lambda: (client.status(exp).pump.get("maintained", 0) > 0
                     and pipeline.fit_executor().snapshot()["lanes"]
                     > before),
            timeout=30.0), "no batchable lane reached the executor"
        st = client.status(exp)
        assert st.pump["executor"]["mean_batch"] >= 1.0
        assert st.pump["quality"]["sparse_max"] >= 1
    finally:
        client.stop(exp)
        client.close()
