"""Partition tolerance: epoch fencing, rebalance-on-add, manager
standby/failover, and the deterministic chaos harness.

The fencing invariants under test (ISSUE 7 acceptance):
  * every observation lands in the log exactly once, across any
    interleaving of partitions, heals, and ownership handovers;
  * no suggestion id is served twice;
  * a fenced incarnation's writes NEVER reach the store.

The chaos tests (marked ``chaos``) replay a seeded, tick-indexed
``FaultPlan`` through the real client/manager transport paths — run by
scripts/ci.sh tier-2 with ``REPRO_CHAOS=1``.
"""
import os
import tempfile
import time

import pytest

from repro.api.local import LocalClient
from repro.api.protocol import (ApiError, CreateExperiment, E_FENCED,
                                E_WRONG_SHARD, ObserveRequest)
from repro.core import ExperimentConfig, Param, Space
from repro.core.faults import FaultPlan, InjectedPartition
from repro.core.store import EPOCH_ZERO, FencedError, Store
from repro.fleet import FleetClient, FleetManager


def chaos(fn):
    return pytest.mark.chaos(pytest.mark.skipif(
        not os.environ.get("REPRO_CHAOS"),
        reason="chaos fault injection (tier-2; set REPRO_CHAOS=1)")(fn))


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg_json(name, budget=6, **kw):
    kw.setdefault("optimizer", "random")
    kw.setdefault("space", _space())
    return dict(ExperimentConfig(name=name, budget=budget, **kw).to_json())


# ------------------------------------------------------------ store fences
def test_store_fence_claim_check_and_optin_semantics():
    store = Store(tempfile.mkdtemp())
    store.create_experiment("e1", ExperimentConfig(
        name="f", budget=2, optimizer="random", space=_space()))
    # no fence record: reads as zero, every check passes (standalone
    # clients never opt into the fencing regime)
    assert store.read_fence("e1") == (EPOCH_ZERO, "")
    store.check_fence("e1", EPOCH_ZERO, "svc-any")
    # first grant claims the record
    assert store.claim_fence("e1", (1, 1), "svc-a") == (1, 1)
    store.check_fence("e1", (1, 1), "svc-a")
    # within an epoch: last adopter wins (owner swap), old owner fenced
    store.claim_fence("e1", (1, 1), "svc-b")
    with pytest.raises(FencedError):
        store.check_fence("e1", (1, 1), "svc-a")
    # across epochs: higher grant always wins; stale claim rejected
    store.claim_fence("e1", (2, 5), "svc-c")
    with pytest.raises(FencedError):
        store.claim_fence("e1", (1, 9), "svc-a")
    with pytest.raises(FencedError):
        store.check_fence("e1", (1, 1), "svc-b")
    assert store.read_fence("e1") == ((2, 5), "svc-c")


def test_epochless_clients_keep_legacy_interleaving():
    """Back-compat guard: two standalone clients over one root (no
    manager, no epochs) must still interleave writes — the fencing
    regime is strictly opt-in."""
    root = tempfile.mkdtemp()
    c1 = LocalClient(root)
    eid = c1.create_experiment(CreateExperiment(
        config=_cfg_json("legacy", budget=4))).exp_id
    s1 = c1.suggest(eid, 1).suggestions[0]
    c2 = LocalClient(root)
    c2.create_experiment(CreateExperiment(config={}, exp_id=eid))
    s2 = c2.suggest(eid, 1).suggestions[0]
    # both incarnations keep writing: no fence record was ever created
    assert c1.observe(ObserveRequest(eid, s1.suggestion_id, s1.assignment,
                                     value=0.5)).accepted
    assert c2.observe(ObserveRequest(eid, s2.suggestion_id, s2.assignment,
                                     value=0.6)).accepted
    assert c1.store.read_fence(eid) == (EPOCH_ZERO, "")


def test_zombie_incarnation_fenced_after_higher_epoch_adoption():
    """The tentpole invariant: once a newer epoch claims the experiment,
    the old incarnation's durable writes are rejected with ``fenced``
    and never reach the observation log."""
    root = tempfile.mkdtemp()
    zombie = LocalClient(root)
    eid = zombie.create_experiment(CreateExperiment(
        config=_cfg_json("fence", budget=6), exp_id="exp-fence",
        epoch=[1, 1])).exp_id
    held = zombie.suggest(eid, 2).suggestions
    assert len(held) == 2

    # a new owner adopts at a higher epoch (manager grant after e.g. a
    # false-positive death during a partition)
    owner = LocalClient(root)
    owner.create_experiment(CreateExperiment(config={}, exp_id=eid,
                                             epoch=[1, 2]))
    # the zombie heals and tries to write: rejected, nothing logged
    with pytest.raises(ApiError) as ei:
        zombie.observe(ObserveRequest(eid, held[0].suggestion_id,
                                      held[0].assignment, value=0.9))
    assert ei.value.code == E_FENCED
    records = owner.store.load_observation_records(eid)
    assert records == [], "fenced write must never reach the log"
    # the zombie stood down: even its cheap hot path answers fenced now
    with pytest.raises(ApiError) as ei:
        zombie.suggest(eid, 1)
    assert ei.value.code == E_FENCED
    with pytest.raises(ApiError) as ei:
        zombie.observe(ObserveRequest(eid, held[1].suggestion_id,
                                      held[1].assignment, value=0.9))
    assert ei.value.code == E_FENCED

    # the rightful owner serves and logs normally — including the ids
    # the zombie handed out (the trial outcome is real data)
    r = owner.observe(ObserveRequest(eid, held[0].suggestion_id,
                                     held[0].assignment, value=0.4))
    assert r.accepted and not r.duplicate
    # ...exactly once: the same id dedupes
    r2 = owner.observe(ObserveRequest(eid, held[0].suggestion_id,
                                      held[0].assignment, value=0.4))
    assert r2.duplicate and not r2.accepted
    records = owner.store.load_observation_records(eid)
    assert len(records) == 1
    assert records[0]["suggestion_id"] == held[0].suggestion_id
    assert owner.status(eid).epoch == [1, 2]


def test_closed_set_rebuilt_from_log_across_adoptions():
    """A suggestion observed under owner N must dedupe under owner N+1:
    the adopting incarnation rebuilds its closed set from the log's
    ``suggestion_id`` column."""
    root = tempfile.mkdtemp()
    a = LocalClient(root)
    eid = a.create_experiment(CreateExperiment(
        config=_cfg_json("dedupe", budget=4), exp_id="exp-dedupe",
        epoch=[1, 1])).exp_id
    s = a.suggest(eid, 1).suggestions[0]
    assert a.observe(ObserveRequest(eid, s.suggestion_id, s.assignment,
                                    value=0.7)).accepted
    b = LocalClient(root)
    b.create_experiment(CreateExperiment(config={}, exp_id=eid,
                                         epoch=[1, 2]))
    # a straggler re-reports the already-logged suggestion to the NEW owner
    r = b.observe(ObserveRequest(eid, s.suggestion_id, s.assignment,
                                 value=0.7))
    assert r.duplicate and not r.accepted
    assert len(b.store.load_observation_records(eid)) == 1


# -------------------------------------------------------- rebalance-on-add
def test_rebalance_on_add_moves_minimal_set_and_transfers_pendings():
    root = tempfile.mkdtemp()
    manager = FleetManager(store=root)
    shards = {f"shard-{i}": LocalClient(root) for i in range(3)}
    for sid, c in shards.items():
        manager.add_shard(c, shard_id=sid)
    client = FleetClient(manager, heartbeat=False)
    exp_ids = []
    pendings = {}
    for i in range(8):
        eid = client.create_experiment(CreateExperiment(
            config=_cfg_json(f"rb-{i}", budget=4),
            exp_id=f"exp-rb-{i:02d}")).exp_id
        exp_ids.append(eid)
        pendings[eid] = {s.suggestion_id: s.assignment
                         for s in client.suggest(eid, 2).suggestions}
    # pick a joining shard id whose ring position actually captures some
    # of our 8 keys (with 64 vnodes a specific name may capture none —
    # the hash is deterministic, so search once and stay deterministic)
    new_sid = next(s for s in (f"shard-new-{i}" for i in range(64))
                   if manager.ring.moved_by_adding(s, exp_ids))
    predicted = set(manager.ring.moved_by_adding(new_sid, exp_ids))
    old_owner = {e: manager.owner_of(e) for e in predicted}

    new_client = LocalClient(root)
    manager.add_shard(new_client, shard_id=new_sid)

    # exactly the predicted minimal set moved, journal completed + cleared
    moved = {ev["exp_id"] for ev in manager.events
             if ev["event"] == "handover"}
    assert moved == predicted
    assert manager.store.read_fleet_state("rebalance") is None
    assert manager.stats["rebalanced"] == len(predicted)
    for eid in exp_ids:
        hosted = manager.owner_of(eid).shard_id
        assert (eid in new_client._exps) == (eid in predicted)
        assert (hosted == new_sid) == (eid in predicted)
    for eid in predicted:
        # the drained owner answers wrong_shard (re-route), never re-adopts
        with pytest.raises(ApiError) as ei:
            old_owner[eid].client.suggest(eid, 1)
        assert ei.value.code == E_WRONG_SHARD
        # fence record granted by the manager's rebalance epoch
        epoch, _ = manager.store.read_fence(eid)
        assert epoch > EPOCH_ZERO and epoch[0] == manager.term

    # transferred pendings are re-served FIRST on the new owner, under
    # their original ids (the constant-liar lie travelled with them)
    probe_eid = sorted(predicted)[0]
    got = client.suggest(probe_eid, 2)
    assert {s.suggestion_id for s in got.suggestions} \
        == set(pendings[probe_eid])

    # every experiment still completes exactly on budget through the
    # router: the outstanding pendings land once, then fresh fills
    for eid in exp_ids:
        seen = set(pendings[eid])
        for sid_, asg in pendings[eid].items():
            r = client.observe(ObserveRequest(eid, sid_, asg, value=0.5))
            assert r.accepted and not r.duplicate
        deadline = time.monotonic() + 20
        while client.status(eid).observations < 4:
            assert time.monotonic() < deadline, eid
            for s in client.suggest(eid, 4).suggestions:
                assert s.suggestion_id not in seen, "id served twice"
                seen.add(s.suggestion_id)
                r = client.observe(ObserveRequest(
                    eid, s.suggestion_id, s.assignment, value=0.5))
                assert r.accepted and not r.duplicate
        st = client.status(eid)
        assert st.observations == 4 and st.pending == 0
    for eid in exp_ids:
        recs = Store(root).load_observation_records(eid)
        ids = [r["suggestion_id"] for r in recs]
        assert len(recs) == 4 and len(set(ids)) == 4, \
            "every observation lands exactly once"
    client.close()


def test_rebalance_journal_rolls_back_when_target_gone():
    root = tempfile.mkdtemp()
    store = Store(root)
    store.write_fleet_state("rebalance", {
        "id": "dead", "to": "shard-ghost", "term": 1,
        "entries": [{"exp_id": "exp-x", "from": "shard-0",
                     "epoch": [1, 3], "done": False}]})
    # a new active manager resumes the journal at construction: the
    # target shard never re-joined, so the handover rolls back cleanly
    manager = FleetManager(store=store)
    assert store.read_fleet_state("rebalance") is None
    assert any(ev["event"] == "rebalance_rollback"
               for ev in manager.events)
    assert "exp-x" not in manager._experiments


# ------------------------------------------------------------ standby
def test_standby_takes_over_resumes_journal_and_fences_old_manager():
    root = tempfile.mkdtemp()
    clients = {f"shard-{i}": LocalClient(root) for i in range(3)}
    active = FleetManager(store=root, manager_id="mgr-a", period=0.1)
    for sid in ("shard-0", "shard-1"):
        active.add_shard(clients[sid], shard_id=sid)
    fc = FleetClient(active, heartbeat=False)
    exp_ids = [fc.create_experiment(CreateExperiment(
        config=_cfg_json(f"ha-{i}", budget=3),
        exp_id=f"exp-ha-{i:02d}")).exp_id for i in range(6)]
    held = {e: fc.suggest(e, 1).suggestions for e in exp_ids}
    fc.beat()   # holdings reach the event tail for the standby to replay

    # the active manager crashes mid-rebalance: shard-2 installed and
    # journaled, but no handover ran yet
    moved = active.ring.moved_by_adding("shard-2", exp_ids)
    assert moved, "need a non-empty disruption set for this test"
    active.add_shard(clients["shard-2"], shard_id="shard-2",
                     rebalance=False)
    active.store.write_fleet_state("rebalance", {
        "id": "j1", "to": "shard-2", "term": active.term,
        "entries": [{"exp_id": e,
                     "from": active._experiments.get(e, ""),
                     "epoch": active._grant_epoch(), "done": False}
                    for e in sorted(moved)]})
    old_term = active.term
    active._renew_lease()   # last sign of life, then "crash"
    active.stop()           # no more lease renewals

    standby = FleetManager(store=root, manager_id="mgr-b", standby=True,
                           period=0.1, lease_timeout=0.2,
                           shard_resolver=lambda sid, url: clients[sid])
    assert standby.role == "standby"
    assert standby.poll_standby() is False, "fresh lease: no takeover"
    time.sleep(0.35)
    assert standby.poll_standby() is True
    assert standby.role == "active" and standby.term == old_term + 1

    # journal resumed at the NEW term: moved experiments live on shard-2
    # with fences that out-rank every grant of the deposed manager
    assert standby.store.read_fleet_state("rebalance") is None
    for eid in moved:
        assert standby._experiments[eid] == "shard-2"
        assert eid in clients["shard-2"]._exps
        epoch, _ = standby.store.read_fence(eid)
        assert epoch[0] == standby.term
    # worker holdings were rebuilt from the event tail
    rec = standby.registry.get(fc.worker_id)
    assert rec is not None and rec.holdings == fc.holdings()
    # the deposed manager notices at its next lease renewal and stands down
    assert active._renew_lease() is False
    assert active.role == "deposed"
    active.tick()   # no-op: a deposed manager must not probe or grant

    # the fleet keeps working through the new manager, exactly on budget
    fc2 = FleetClient(standby, heartbeat=False)
    for eid in exp_ids:
        seen = {s.suggestion_id for s in held[eid]}
        for s in held[eid]:     # old pendings still land exactly once
            r = fc2.observe(ObserveRequest(eid, s.suggestion_id,
                                           s.assignment, value=0.5))
            assert r.accepted and not r.duplicate
        deadline = time.monotonic() + 20
        while fc2.status(eid).observations < 3:
            assert time.monotonic() < deadline, eid
            for s in fc2.suggest(eid, 3).suggestions:
                assert s.suggestion_id not in seen
                seen.add(s.suggestion_id)
                assert fc2.observe(ObserveRequest(
                    eid, s.suggestion_id, s.assignment,
                    value=0.5)).accepted
        ids = [r["suggestion_id"]
               for r in Store(root).load_observation_records(eid)]
        assert len(ids) == 3 and len(set(ids)) == 3
    fc.close()
    fc2.close()


# ------------------------------------------------------------ chaos harness
def _drive(client, exp_ids, seen, budget):
    """One best-effort suggest/observe round per experiment; returns how
    many experiments are complete.  Transport failures (injected) are
    retried on later rounds — exactly what a scheduler does.  ``seen``
    accumulates *observed* ids per experiment: a requeue/transfer may
    legitimately re-serve an un-observed pending, but an id that already
    landed in the log must never be handed out again."""
    done = 0
    for eid in exp_ids:
        try:
            st = client.status(eid)
            if st.observations >= budget:
                done += 1
                continue
            for s in client.suggest(eid, 2).suggestions:
                assert s.suggestion_id not in seen[eid], \
                    f"{eid}: re-served an already-observed id"
                r = client.observe(ObserveRequest(
                    eid, s.suggestion_id, s.assignment, value=0.5))
                assert not r.duplicate, f"{eid}: duplicate observe"
                if r.accepted:
                    seen[eid].add(s.suggestion_id)
        except (ApiError, InjectedPartition, ConnectionRefusedError):
            continue    # partitioned this round — retry after heal
    return done


@chaos
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_chaos_partition_heal_rebalance_exactly_once(seed):
    """Acceptance: a seeded fault plan interleaving client↔shard
    partitions, a manager↔shard partition long enough to declare the
    shard dead (adoption + zombie), a heal, and a live shard-add
    rebalance — k=8 experiments all complete exactly on budget, no
    suggestion id is ever served twice, and the zombie's post-heal
    writes are rejected with ``fenced``."""
    budget, k = 4, 8
    root = tempfile.mkdtemp()
    plan = FaultPlan(seed=seed)
    # schedule: the worker loses shard-1 for a while (routed retries),
    # the manager loses shard-2 for long enough to declare it dead
    plan.partition("w-chaos", "shard-1", at=3, until=9)
    plan.partition("manager", "shard-2", at=5)
    manager = FleetManager(store=root, period=0.05, probe_timeout=0.5,
                           fault_plan=plan)
    shards = {f"shard-{i}": LocalClient(root) for i in range(3)}
    for sid, c in shards.items():
        manager.add_shard(c, shard_id=sid)
    client = FleetClient(manager, worker_id="w-chaos", heartbeat=False,
                         fault_plan=plan)
    exp_ids = [client.create_experiment(CreateExperiment(
        config=_cfg_json(f"chaos-{i}", budget=budget),
        exp_id=f"exp-chaos-{i:02d}")).exp_id for i in range(k)]
    seen = {e: set() for e in exp_ids}
    victims = [e for e in exp_ids
               if manager.owner_of(e).shard_id == "shard-2"]

    added = False
    for round_no in range(200):
        manager.tick()          # advances plan tick + probes + sweeps
        done = _drive(client, exp_ids, seen, budget)
        if manager.stats["dead_shards"] >= 1 and not added:
            # shard-2 was declared dead (its experiments adopted at a
            # fresh epoch); now heal everything and add a new shard so
            # a rebalance interleaves with the tail of the run
            plan.heal()
            shards["shard-3"] = LocalClient(root)
            manager.add_shard(shards["shard-3"], shard_id="shard-3")
            added = True
        elif added and done == len(exp_ids):
            break
        time.sleep(0.02)
    assert added, "fault plan must declare shard-2 dead"

    # the zombie shard healed: its in-memory state is intact, but every
    # durable write it attempts is fenced and never reaches the log
    for eid in victims:
        if shards["shard-2"]._exps.get(eid) is None:
            continue
        with pytest.raises(ApiError) as ei:
            shards["shard-2"].observe(ObserveRequest(
                eid, "zombie-sid", {"x": 0.5}, value=0.1))
        assert ei.value.code == E_FENCED
    if victims:
        assert manager.stats["adopted"] >= len(victims)

    # every budget completes exactly; every observation landed exactly once
    store = Store(root)
    for eid in exp_ids:
        deadline = time.monotonic() + 30
        while client.status(eid).observations < budget:
            assert time.monotonic() < deadline, eid
            _drive(client, [eid], seen, budget)
        recs = store.load_observation_records(eid)
        ids = [r["suggestion_id"] for r in recs]
        assert len(recs) == budget, eid
        assert len(set(ids)) == budget, f"{eid}: duplicate log entry"
        st = client.status(eid)
        assert st.observations == budget and st.pending == 0
    client.close()


@chaos
def test_chaos_manager_kill_mid_rebalance_standby_resumes():
    """Acceptance: kill the active manager mid-rebalance (journal
    written, handovers incomplete) — the standby takes over, resumes the
    journal at a higher term, and every experiment completes exactly."""
    budget, k = 3, 8
    root = tempfile.mkdtemp()
    clients = {f"shard-{i}": LocalClient(root) for i in range(4)}
    active = FleetManager(store=root, manager_id="mgr-a", period=0.05)
    for i in range(3):
        active.add_shard(clients[f"shard-{i}"], shard_id=f"shard-{i}")
    fc = FleetClient(active, heartbeat=False)
    exp_ids = [fc.create_experiment(CreateExperiment(
        config=_cfg_json(f"mk-{i}", budget=budget),
        exp_id=f"exp-mk-{i:02d}")).exp_id for i in range(k)]
    held = {e: fc.suggest(e, 1).suggestions for e in exp_ids}
    moved = sorted(active.ring.moved_by_adding("shard-3", exp_ids))
    assert moved
    # crash exactly between journal write and the first handover
    active.add_shard(clients["shard-3"], shard_id="shard-3",
                     rebalance=False)
    active.store.write_fleet_state("rebalance", {
        "id": "jX", "to": "shard-3", "term": active.term,
        "entries": [{"exp_id": e, "from": active._experiments.get(e, ""),
                     "epoch": active._grant_epoch(), "done": False}
                    for e in moved]})
    active.stop()

    standby = FleetManager(store=root, manager_id="mgr-b", standby=True,
                           period=0.05, lease_timeout=0.15,
                           shard_resolver=lambda sid, url: clients[sid])
    deadline = time.monotonic() + 10
    while not standby.poll_standby():
        assert time.monotonic() < deadline
        time.sleep(0.05)
    assert standby.store.read_fleet_state("rebalance") is None
    for eid in moved:
        assert standby._experiments[eid] == "shard-3"

    fc2 = FleetClient(standby, heartbeat=False)
    seen = {e: set() for e in exp_ids}
    for eid in exp_ids:
        # the dead manager's clients still hold one pending each; they
        # land exactly once wherever the experiment now lives
        for s in held[eid]:
            r = fc2.observe(ObserveRequest(eid, s.suggestion_id,
                                           s.assignment, value=0.5))
            assert r.accepted and not r.duplicate
            seen[eid].add(s.suggestion_id)
    deadline = time.monotonic() + 30
    while _drive(fc2, exp_ids, seen, budget) < len(exp_ids):
        assert time.monotonic() < deadline
    store = Store(root)
    for eid in exp_ids:
        ids = [r["suggestion_id"]
               for r in store.load_observation_records(eid)]
        assert len(ids) == budget and len(set(ids)) == budget
    fc.close()
    fc2.close()
