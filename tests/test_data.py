"""Data pipeline: determinism, sharding partition, prefetch, resume."""
import numpy as np

from repro.data import DataConfig, TokenPipeline


def _cfg(**kw):
    base = dict(vocab_size=977, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = TokenPipeline(_cfg()).batch_at(5)
    b = TokenPipeline(_cfg()).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_shards_partition_global_batch():
    full = TokenPipeline(_cfg()).batch_at(3)["tokens"]
    parts = [TokenPipeline(_cfg(num_shards=4, shard_id=i)).batch_at(3)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_labels_shifted():
    b = TokenPipeline(_cfg()).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_matches_sync_and_resumes():
    pipe = TokenPipeline(_cfg()).start_prefetch(from_step=10)
    try:
        step, batch = pipe.next_prefetched()
        assert step == 10
        np.testing.assert_array_equal(
            batch["tokens"], TokenPipeline(_cfg()).batch_at(10)["tokens"])
    finally:
        pipe.stop_prefetch()


def test_tokens_in_vocab():
    b = TokenPipeline(_cfg()).batch_at(2)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 977
