"""Space: codec roundtrips and validity (hypothesis property tests)."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the image
from hypothesis import given, settings, strategies as st

from repro.core.space import Param, Space


def _space():
    return Space([
        Param("lr", "double", 1e-5, 1e-1, log=True),
        Param("width", "int", 8, 512),
        Param("act", "categorical", choices=("relu", "gelu", "silu")),
        Param("frac", "double", 0.0, 1.0),
    ])


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_sample_valid_and_roundtrip(seed):
    space = _space()
    rng = np.random.default_rng(seed)
    for a in space.sample(rng, 5):
        assert space.validate(a)
        u = space.to_unit(a)
        assert np.all((u >= 0) & (u <= 1))
        b = space.from_unit(u)
        assert space.validate(b)
        # codec is idempotent on its own output
        assert np.allclose(space.to_unit(b), space.to_unit(a), atol=1e-6)


@given(st.lists(st.floats(0, 1), min_size=4, max_size=4))
@settings(max_examples=50, deadline=None)
def test_from_unit_always_valid(u):
    space = _space()
    assert space.validate(space.from_unit(np.array(u)))


def test_grid_covers_categoricals():
    space = _space()
    g = space.grid(2)
    assert {a["act"] for a in g} == {"relu", "gelu", "silu"}
    assert all(space.validate(a) for a in g)


def test_config_roundtrip():
    space = _space()
    again = Space.from_config(space.to_config())
    assert again.names == space.names
    a = space.sample(np.random.default_rng(0), 1)[0]
    assert np.allclose(space.to_unit(a), again.to_unit(a))


def test_log_param_needs_positive_low():
    with pytest.raises(ValueError):
        Param("x", "double", 0.0, 1.0, log=True)
