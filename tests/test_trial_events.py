"""Trial-events subsystem (service-side early stopping): decision parity
across backends, multi-rung crossing semantics, worker-side report
throttling, checkpoint-aware pause/resume with lease accounting, rung-state
durability across service restarts, and the paper's multi-scheduler
scenario (one shared rung table for N workers)."""
import json
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.api import (CreateExperiment, Decision, HTTPClient, LocalClient,
                       ReportRequest, serve_api)
from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)
from repro.core.suggest import ASHA


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg(name="events", budget=6, parallel=2, **kw):
    kw.setdefault("optimizer", "random")
    kw.setdefault("early_stop", {"min_steps": 1, "eta": 2})
    return ExperimentConfig(name=name, budget=budget, parallel=parallel,
                            space=_space(), **kw)


def _create(client, cfg, exp_id=None):
    return client.create_experiment(
        CreateExperiment(config=cfg.to_json(), exp_id=exp_id))


# ------------------------------------------------------------ ASHA semantics
def test_asha_multi_rung_jump_evaluated_at_every_crossed_rung():
    """A report that jumps past several rungs must be judged at EVERY
    crossed rung: failing a low rung can't be masked by a pass higher up."""
    asha = ASHA(min_steps=1, eta=2, max_rungs=4)   # rungs 1, 2, 4, 8
    # good trials populate rungs 1..4 (ascending, so each is top on entry)
    for t, v in (("a", 0.8), ("b", 0.9), ("c", 1.0)):
        assert asha.report(t, 4, v) == "continue"
    # bad trial jumps straight to step 4: outside top-1/2 at rung 1
    # already — must stop even though it "reached" rung 4
    assert asha.report("bad", 4, 0.1) == "stop"
    # ...and the decision is final: a later (reordered/duplicate) report
    # at a higher step cannot resurrect it
    assert asha.report("bad", 8, 2.0) == "stop"
    # recorded at the failing rung, but NOT above it: an unpromoted trial
    # must not pad higher-rung populations (that would loosen their
    # top-1/eta cut for everyone else)
    st = asha.state()
    assert 0.1 in st["values"]["1"]
    assert 0.1 not in st["values"]["2"] and 0.1 not in st["values"]["4"]


def test_asha_stop_mode_judges_each_rung_once():
    """A between-rung report (noisy metric dip) must not retro-fail a
    rung the trial already passed — stop mode evaluates a rung exactly
    once, when first crossed."""
    asha = ASHA(min_steps=1, eta=3, max_rungs=3)   # rungs 1, 3, 9
    for i in range(8):
        asha.report(f"t{i}", 1, 0.1 * (i + 1))
    # the best trial passed rung 1 (0.8, cutoff covers top 1/3)
    assert asha.report("t7", 1, 0.8) == "continue"
    # transient dip at step 2 (no new rung crossed): must NOT stop it
    assert asha.report("t7", 2, 0.05) == "continue"
    # ...whereas in pause mode the re-check IS the promotion mechanism
    pauser = ASHA(min_steps=1, eta=2, max_rungs=2, mode="pause")
    pauser.report("a", 1, 0.9)
    assert pauser.report("b", 1, 0.1) == "pause"
    for t, v in (("c", 0.01), ("d", 0.02)):
        pauser.report(t, 1, v)
    assert pauser.report("b", 1, 0.1) == "continue"   # promoted


def test_asha_state_roundtrips_through_json():
    asha = ASHA(min_steps=1, eta=3, max_rungs=3)
    for t, s, v in (("a", 1, 0.5), ("b", 3, 0.8), ("a", 3, 0.4),
                    ("c", 1, 0.1)):
        asha.report(t, s, v)
    wire = json.loads(json.dumps(asha.state()))
    clone = ASHA(min_steps=1, eta=3, max_rungs=3)
    clone.restore(wire)
    assert clone.state() == asha.state()
    # the clone keeps deciding identically
    assert clone.report("d", 1, 0.05) == asha.report("d", 1, 0.05)


# -------------------------------------------------- backend decision parity
def _stream():
    """A report stream with early leaders, stragglers, and rung jumps."""
    rng = np.random.default_rng(7)
    stream = []
    for i in range(8):
        tid = f"t{i:02d}"
        v = float(rng.uniform())
        for step in (1, 2, 4, 8):
            stream.append((tid, step, v * step / 8.0))
    rng.shuffle(stream)
    return stream


def test_http_and_local_backends_return_identical_decisions():
    cfg = _cfg(budget=50)
    local = LocalClient(tempfile.mkdtemp())
    exp_l = _create(local, cfg).exp_id
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        http = HTTPClient(server.url)
        exp_h = _create(http, cfg).exp_id
        decisions_l, decisions_h = [], []
        for tid, step, v in _stream():
            dl = local.report(ReportRequest(exp_l, tid, step, v))
            dh = http.report(ReportRequest(exp_h, tid, step, v))
            decisions_l.append(dl)
            decisions_h.append(dh)
        assert decisions_l == decisions_h
        assert any(d.decision == "stop" for d in decisions_l), \
            "the stream is adversarial enough that someone must stop"
        # identical rung tables too
        sl = local._exps[exp_l].stopper.state()
        sh = server.backend._exps[exp_h].stopper.state()
        assert sl == sh
    finally:
        server.shutdown()


def test_report_with_non_numeric_fields_is_bad_request():
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        http = HTTPClient(server.url)
        exp = _create(http, _cfg()).exp_id
        from repro.api import ApiError
        with pytest.raises(ApiError) as ei:
            http._call("POST", f"/v1/experiments/{exp}/trials/t1/report",
                       {"step": "abc", "value": 0.5})
        assert ei.value.code == "bad_request"
        with pytest.raises(ApiError) as ei:
            http._call("POST", f"/v1/experiments/{exp}/trials/t1/report",
                       {"value": 0.5})
        assert ei.value.code == "bad_request"
    finally:
        server.shutdown()


def test_report_without_early_stop_still_persists_metrics():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(early_stop=None)).exp_id
    for step in (1, 2, 3):
        d = client.report(ReportRequest(exp, "t01", step, 0.5))
        assert d.decision == "continue" and d.next_rung is None
    recs = client.store.load_metrics(exp, "t01")
    assert [r["step"] for r in recs] == [1, 2, 3]
    assert [r["seq"] for r in recs] == [1, 2, 3]


# ------------------------------------------------------- worker-side batching
def test_report_every_throttles_but_never_skips_a_rung():
    """With report_every=5 a tight loop coalesces service calls, yet every
    rung boundary still reaches the service (Decision.next_rung)."""
    orch = Orchestrator(tempfile.mkdtemp())
    cfg = _cfg(name="throttle", budget=2, parallel=2, report_every=5,
               early_stop={"min_steps": 4, "eta": 2, "max_rungs": 2})
    client = orch.client

    def trial(a, ctx):
        for step in range(1, 20):       # 19 reports from the trial loop
            ctx.report(step, float(step))   # tied values: nobody pruned
        return a["x"]

    exp = orch.run(cfg, trial_fn=trial)
    by_trial = _metrics_by_trial(client, exp)
    assert len(by_trial) == 2
    for key, recs in by_trial.items():
        steps = [r["step"] for r in recs]
        # rungs are 4 and 8: both boundaries must have gone through
        assert any(s >= 4 for s in steps) and any(s >= 8 for s in steps)
        # throttle: far fewer service calls than the 19 loop reports
        assert len(steps) <= 6, steps


def _metrics_by_trial(client, exp):
    out = {}
    for rec in client.store.load_metrics(exp):
        out.setdefault(rec["trial_key"], []).append(rec)
    return out


def test_same_step_reports_coalesce_to_one_service_call():
    orch = Orchestrator(tempfile.mkdtemp())
    cfg = _cfg(name="coalesce", budget=1, parallel=1, early_stop=None)

    def trial(a, ctx):
        for _ in range(50):
            ctx.report(1, a["x"])       # a tight loop re-reporting step 1
        return a["x"]

    exp = orch.run(cfg, trial_fn=trial)
    recs = orch.client.store.load_metrics(exp)
    assert len(recs) == 1, "same-step repeats must not DoS the service"


# ------------------------------------------------- pause / resume lifecycle
def test_pause_releases_lease_and_resumes_from_checkpoint():
    """mode='pause': a below-threshold trial is parked (lease returned to
    the pool, suggestion kept pending) and later resumed from its
    checkpoint at the step it paused at."""
    orch = Orchestrator(tempfile.mkdtemp())
    orch.cluster_create({"cluster_name": "pp",
                         "pools": [{"name": "tpu", "resource": "tpu",
                                    "chips": 2}]})
    client = orch.client
    cfg = _cfg(name="pause", budget=2, parallel=1,
               resources=Resources(pool="tpu", chips=2),
               early_stop={"min_steps": 1, "eta": 2, "mode": "pause"})
    exp = _create(client, cfg).exp_id
    # pre-seed the rung table with a strong trial so every scheduler trial
    # is outside the top-1/2 at every rung -> deterministic pauses
    for step in (1, 2, 4):
        client.report(ReportRequest(exp, "warm", step, 9.0))

    runs = []           # (run_id, resume_step) per execution

    def trial(a, ctx):
        runs.append((ctx.trial_id, ctx.resume_step))
        start = ctx.resume_step or 0
        for step in (1, 2, 4):
            if step <= start:
                continue                # resumed: skip already-done work
            ctx.report(step, a["x"])
        return a["x"]

    orch.run(cfg, trial_fn=trial, exp_id=exp)

    # every execution paused at least once and resumed from its marker
    resumed = [(rid, rs) for rid, rs in runs if rs]
    assert resumed, f"expected paused->resumed executions, got {runs}"
    assert all(rs in (1, 2, 4) for _, rs in resumed)
    # paused re-runs carry the -pN suffix and a growing resume step
    assert any("-p" in rid for rid, _ in resumed)
    # all leases returned to the pool
    assert orch.cluster_status("pp")["pools"]["tpu"]["free"] == 2
    # the experiment still completed its budget: re-pauses with no new
    # information were finalized as pruned partial observations
    obs = orch.store.load_observations(exp)
    assert len(obs) == 2
    assert all(o.metadata.get("pruned") and o.metadata.get("paused")
               for o in obs)
    st = client.status(exp)
    assert st.pending == 0, "no pending suggestion may leak"


def test_pause_decision_parity_between_backends():
    cfg = _cfg(budget=10,
               early_stop={"min_steps": 1, "eta": 2, "mode": "pause"})
    local = LocalClient(tempfile.mkdtemp())
    exp_l = _create(local, cfg).exp_id
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        http = HTTPClient(server.url)
        exp_h = _create(http, cfg).exp_id
        for tid, step, v in (("a", 1, 0.9), ("b", 1, 0.1), ("b", 2, 0.2)):
            dl = local.report(ReportRequest(exp_l, tid, step, v))
            dh = http.report(ReportRequest(exp_h, tid, step, v))
            assert dl == dh
        assert dl.decision == "pause"   # 'b' is parked, not killed
        # promotion: once the rung population turns over, 'b' continues
        for tid, v in (("c", 0.05), ("d", 0.06), ("e", 0.07)):
            local.report(ReportRequest(exp_l, tid, 1, v))
        assert local.report(
            ReportRequest(exp_l, "b", 2, 0.2)).decision == "continue"
    finally:
        server.shutdown()


# ----------------------------------------------- durability across restarts
def test_rung_state_survives_service_restart():
    """Kill the service (drop the LocalClient), resume on the same store:
    the rung table must be byte-identical — snapshot fast path."""
    root = tempfile.mkdtemp()
    cfg = _cfg(budget=50)
    c1 = LocalClient(root)
    exp = _create(c1, cfg).exp_id
    for tid, step, v in _stream():
        c1.report(ReportRequest(exp, tid, step, v))
    pre = c1._exps[exp].stopper.state()
    pre_seq = c1._exps[exp].metric_seq

    c2 = LocalClient(root)                      # "restarted" service
    resp = _create(c2, cfg, exp_id=exp)
    assert resp.resumed
    assert c2._exps[exp].stopper.state() == pre
    assert c2._exps[exp].metric_seq == pre_seq
    # decisions continue identically post-restart
    assert (c1.report(ReportRequest(exp, "fresh", 1, 0.0)).decision
            == c2.report(ReportRequest(exp, "fresh", 1, 0.0)).decision)


def test_rung_state_rebuilt_from_metric_log_when_snapshot_lost():
    """Same restart, but the snapshot is gone (crash before the status
    write): the per-trial metric logs replay in seq order to the exact
    same rung table."""
    root = tempfile.mkdtemp()
    cfg = _cfg(budget=50)
    c1 = LocalClient(root)
    exp = _create(c1, cfg).exp_id
    for tid, step, v in _stream():
        c1.report(ReportRequest(exp, tid, step, v))
    pre = c1._exps[exp].stopper.state()

    # simulate losing the snapshot
    st_path = c1.store.exp_dir(exp) / "status.json"
    st = json.loads(st_path.read_text())
    assert st.pop("rungs", None) is not None
    st_path.write_text(json.dumps(st))

    c2 = LocalClient(root)
    _create(c2, cfg, exp_id=exp)
    assert c2._exps[exp].stopper.state() == pre


def test_metric_seq_stays_monotone_across_restart_without_early_stop():
    """Even with no stopping policy, a restarted service must pick up the
    metric-stream high-water mark — seq numbers are never reused."""
    root = tempfile.mkdtemp()
    cfg = _cfg(early_stop=None)
    c1 = LocalClient(root)
    exp = _create(c1, cfg).exp_id
    for step in (1, 2, 3):
        c1.report(ReportRequest(exp, "t01", step, 0.5))

    c2 = LocalClient(root)                      # "restarted" service
    _create(c2, cfg, exp_id=exp)
    d = c2.report(ReportRequest(exp, "t01", 4, 0.5))
    assert d.seq == 4
    seqs = [r["seq"] for r in c2.store.load_metrics(exp)]
    assert seqs == [1, 2, 3, 4]


# ------------------------------------- the paper's multi-scheduler scenario
def test_two_schedulers_share_one_rung_table_and_resume():
    """Two full Schedulers drive ONE experiment over HTTP: pruning
    decisions come from one shared rung table (a trial below threshold is
    stopped no matter which worker runs it), and the rung state survives
    a service restart + --resume."""
    service_root = tempfile.mkdtemp()
    server = serve_api(service_root).start()
    try:
        client = HTTPClient(server.url)
        cfg = _cfg(name="shared-asha", budget=16, parallel=2,
                   early_stop={"min_steps": 1, "eta": 2})
        exp = _create(client, cfg).exp_id

        def trial(a, ctx):
            for step in (1, 2, 4):
                time.sleep(0.002)
                ctx.report(step, a["x"] * step)
            return a["x"]

        def run_worker():
            orch = Orchestrator(tempfile.mkdtemp())
            orch.run(_cfg(name="shared-asha", budget=16, parallel=2,
                          early_stop={"min_steps": 1, "eta": 2}),
                     trial_fn=trial, exp_id=exp, service=server.url)

        workers = [threading.Thread(target=run_worker) for _ in range(2)]
        for t in workers:
            t.start()
        for t in workers:
            t.join(120)
        st = client.status(exp)
        assert st.observations == 16 and st.pending == 0

        backend = server.backend
        obs = backend.store.load_observations(exp)
        pruned = [o for o in obs if o.metadata.get("pruned")]
        full = [o for o in obs if not o.metadata.get("pruned")
                and not o.failed]
        assert pruned, "shared ASHA should prune someone"
        # compare the underlying x, not recorded values: a pruned
        # observation's value is its x*step metric at the prune point
        # (up to 4x), so a value-mean comparison mixes scales and flips
        # when a mid-strength trial is pruned at a late rung — which
        # async rung arrival orders legitimately allow
        x = lambda o: o.assignment["x"]                      # noqa: E731
        assert np.mean([x(o) for o in full]) > \
            np.mean([x(o) for o in pruned])
        # deterministic anchor: the incumbent's metric (x*step) is the
        # running max at every rung, always in the top 1/eta of anything
        # seen so far — shared ASHA can never prune it, regardless of
        # which worker runs it or in what order reports land
        assert max(obs, key=x) in full
        # consistency: pruning is service-side, so the stopped set and the
        # pruned observations line up one-to-one — a trial stopped on one
        # worker's rung data is stopped, period (suggestion ids key the
        # rung table, so the two workers' identically-numbered local
        # trials never collide)
        stopper = backend._exps[exp].stopper
        pre = stopper.state()
        assert len(pre["stopped"]) == len(pruned)
        metric_keys = {r["trial_key"]
                       for r in backend.store.load_metrics(exp)}
        assert set(pre["stopped"]) <= metric_keys

        # restart the service over the same store, resume the experiment
        server.shutdown()
        server2 = serve_api(service_root).start()
        try:
            client2 = HTTPClient(server2.url)
            resp = _create(client2, cfg, exp_id=exp)
            assert resp.resumed and resp.observations == 16
            assert server2.backend._exps[exp].stopper.state() == pre
        finally:
            server2.shutdown()
            server2 = None
    finally:
        try:
            server.shutdown()
        except Exception:
            pass
