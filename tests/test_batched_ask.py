"""ISSUE 10 contracts: the batched ask plane.

``gp.batched_select`` stacks several experiments' q-EI batch selections
on a lane axis and runs them in ONE vmap'd dispatch; the pump publishes
refill demand as ``AskSpec`` snapshots the FitExecutor gathers by
(runner, bucket, k_pad, pool-shape) group.  This file pins the
equivalence (batched picks == serial picks), the compile discipline
(one XLA compile per (bucket, k_pad, lane-pad) triple), the
variable-step fit-lane merge (frozen-lane params bit-identical, the
steps-free group key co-batches mixed ladder rungs), the PRIO_MISS
latency contract (miss asks never wait out the gather window) and —
under REPRO_CONTENTION — a 16-experiment live-pump run through the
shared executor."""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.api import (CreateExperiment, LocalClient, ObserveRequest,
                       pipeline)
from repro.api.pipeline import (BatchableAsk, BatchableFit, FitExecutor,
                                FitLane, PRIO_IDLE, PRIO_MISS)
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space, strip_internal
from repro.core.suggest import Observation, gp, make_optimizer
from repro.core.suggest.bayesopt import run_ask_lanes


def _space():
    return Space([Param("x", "double", 0, 1),
                  Param("y", "double", 1e-4, 1e0, log=True)])


def _f(a):
    return -((a["x"] - 0.62) ** 2 + (np.log10(a["y"]) + 2.0) ** 2)


def _wait(predicate, timeout=10.0, every=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(every)
    return predicate()


def _posteriors(k, n=14, d=3, bucket=32, seed=0):
    """k fitted GP posteriors over distinct histories, one shape bucket,
    with (candidate pool, incumbent) per lane."""
    rng = np.random.default_rng(seed)
    lanes = []
    for i in range(k):
        x = rng.random((n + i, d))
        w = rng.random(d)
        y = np.sin(3.0 * x @ w) + 0.1 * rng.standard_normal(n + i)
        post = gp.fit_gp(x, y, steps=25, bucket=bucket)
        cand = rng.random((64, d)).astype(np.float32)
        lanes.append((post, cand, float(np.max(y))))
    return lanes


# ---------------------------------------------------- gp.batched_select
def test_batched_select_matches_serial_select():
    """k lanes through one vmap'd q-EI dispatch must pick the *same
    candidates* as k independent select_batch calls (exact index
    equality — the suggestion parity contract, atol-free), and land on
    the same lie-folded posterior up to float32 program-order rounding
    (the lane-stacked solves are a different XLA program, so alpha/chol
    drift at the 1e-4 level on O(10) magnitudes)."""
    import jax
    lanes = _posteriors(4)
    ks = [2, 5, 8, 3]
    out = gp.batched_select(
        [(post, cand, best, k)
         for (post, cand, best), k in zip(lanes, ks)])
    for (post, cand, best), k, (picks, lane_post) in zip(lanes, ks, out):
        solo_picks, solo_post = gp.select_batch(post, cand, best, k)
        np.testing.assert_array_equal(np.asarray(picks),
                                      np.asarray(solo_picks))
        for got, want in zip(jax.tree.leaves(lane_post),
                             jax.tree.leaves(solo_post)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=5e-4)


def test_batched_select_one_dispatch_one_compile():
    """One (bucket, k_pad, lane-pad) triple costs exactly one XLA
    compile: varying per-lane k and lane counts within the same pads
    reuse it."""
    lanes = _posteriors(4, seed=7)
    items = [(post, cand, best, 1 + i)
             for i, (post, cand, best) in enumerate(lanes)]
    before = gp._select_lanes._cache_size()
    gp.batched_select(items[:2])                    # lane_pad(2) == 2
    mid = gp._select_lanes._cache_size()
    assert mid == before + 1
    gp.batched_select(list(reversed(items[:2])))    # different k order
    gp.batched_select(items[2:4])                   # different lanes/k
    assert gp._select_lanes._cache_size() == mid
    gp.batched_select(items[:1])                    # lane_pad(1) == 1
    assert gp._select_lanes._cache_size() == mid + 1


def test_prewarm_compiles_select_lanes():
    """Satellite: ``prewarm_bucket(select_lanes=(1, 2))`` compiles the
    batched-select variant per lane pad — a later first dispatch at
    those pads must not add a compile."""
    d, bucket, m = 5, 16, 32         # distinctive shapes: a fresh probe
    before = gp._select_lanes._cache_size()
    gp.prewarm_bucket(d, bucket, fit_steps=(5,), k_pads=(1,),
                      n_cand=m, select_lanes=(1, 2))
    after = gp._select_lanes._cache_size()
    assert after == before + 2       # lane pads 1 and 2
    # idempotent: re-warming and real dispatches at those pads reuse it
    gp.prewarm_bucket(d, bucket, fit_steps=(5,), k_pads=(1,),
                      n_cand=m, select_lanes=(1, 2))
    rng = np.random.default_rng(0)
    x = rng.random((6, d))
    y = rng.standard_normal(6)
    post = gp.fit_gp(x, y, steps=5, bucket=bucket)
    cand = rng.random((m, d)).astype(np.float32)
    gp.batched_select([(post, cand, 1.0, 4), (post, cand, 1.0, 2)])
    assert gp._select_lanes._cache_size() == after


# ---------------------------------------------- variable-step fit lanes
def test_mixed_step_lanes_bitidentical_to_own_step_count():
    """Tentpole (2): lanes on different step budgets merge into one
    masked max(steps) loop, and a lane frozen at its own budget holds
    exactly the parameters a uniform run at that budget produces —
    bit-identical at matched lane pad, and within float tolerance of a
    true solo fit (whose different lane pad is a different XLA program,
    so only rounding-level drift is allowed)."""
    rng = np.random.default_rng(1)
    d = 3
    x1 = rng.random((10, d)); y1 = np.sin(x1.sum(1))
    x2 = rng.random((13, d)); y2 = np.cos(x2.sum(1))
    mixed = gp.batched_fit([(x1, y1, None), (x2, y2, None)],
                           steps=[15, 45], bucket=16)
    lo = gp.batched_fit([(x1, y1, None), (x2, y2, None)],
                        steps=[15, 15], bucket=16)
    hi = gp.batched_fit([(x1, y1, None), (x2, y2, None)],
                        steps=[45, 45], bucket=16)
    for got, want in ((mixed[0], lo[0]), (mixed[1], hi[1])):
        assert np.array_equal(np.asarray(got.log_ls),
                              np.asarray(want.log_ls))
        assert np.array_equal(np.asarray(got.log_amp),
                              np.asarray(want.log_amp))
        assert np.array_equal(np.asarray(got.log_noise),
                              np.asarray(want.log_noise))
    solo = gp.batched_fit([(x1, y1, None)], steps=15, bucket=16)[0]
    np.testing.assert_allclose(mixed[0].log_ls, solo.log_ls, atol=1e-5)
    np.testing.assert_allclose(mixed[0].log_amp, solo.log_amp, atol=1e-5)


def test_fit_group_key_drops_steps():
    """Tentpole (2): two experiments on different warm-step ladder rungs
    (different ``FitSpec.steps``) share a (runner, bucket) group and
    co-batch into ONE dispatch — ``mean_batch`` > 1 under a mixed-step
    workload, the PR 8 ROADMAP follow-up."""
    opts = []
    for i, steps in enumerate((8, 24)):
        opt = make_optimizer("gp", _space(), seed=i, n_init=4,
                             fit_steps=30, warm_fit_steps=steps)
        rng = np.random.default_rng(i)
        opt.tell([Observation(a, _f(a))
                  for a in opt.space.sample(rng, 20)])
        assert opt.maintain()           # cold fit -> warm-started
        opt.tell([Observation(a, _f(a))
                  for a in opt.space.sample(rng, 8)])
        assert opt.maintenance_due()
        opts.append(opt)
    specs = [opt.fit_spec() for opt in opts]
    assert specs[0].steps != specs[1].steps
    assert specs[0].group_key == specs[1].group_key

    installed = []
    ex = FitExecutor(workers=1)
    try:
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i, spec in enumerate(specs):
            ex.submit(f"e{i}", BatchableFit(
                lambda s=spec: FitLane(
                    s, lambda p, dt, s=s: (s.install(p, dt),
                                           installed.append(p)))),
                PRIO_IDLE)
        gate.set()
        assert _wait(lambda: len(installed) == 2)
        snap = ex.snapshot()
        assert snap["batched"] == 1 and snap["lanes"] == 2
        assert snap["mean_batch"] == pytest.approx(2.0)
    finally:
        ex.stop()
    for opt in opts:
        assert opt._params is not None
        assert np.all(np.isfinite(np.asarray(opt._params.log_ls)))


# -------------------------------------------------- AskSpec + executor
def test_ask_spec_parity_with_inline_ask():
    """Satellite: an ``ask_spec`` snapshot run through ``run_ask_lanes``
    and installed must mint the same suggestions an inline ``ask`` on a
    twin optimizer produces (same seed, same history, same rng path)."""
    twins = []
    for _ in range(2):
        opt = make_optimizer("gp", _space(), seed=5, n_init=4,
                             fit_steps=20, warm_fit_steps=10)
        rng = np.random.default_rng(5)
        opt.tell([Observation(a, _f(a))
                  for a in opt.space.sample(rng, 16)])
        twins.append(opt)
    inline = twins[0].ask(4)
    spec = twins[1].ask_spec(4)
    assert spec is not None and spec.k == 4
    out, dt = run_ask_lanes([spec])
    batched = spec.install(out[0], dt)
    assert len(batched) == len(inline) == 4
    for a, b in zip(inline, batched):
        assert strip_internal(a) == strip_internal(b)
    # both twins registered one lie per suggestion
    assert len(twins[0]._pending) == len(twins[1]._pending)


def test_executor_ask_stats_separate_from_fit_stats():
    """Ask dispatches land on batched_asks/ask_lanes; the fit-side
    batched/lanes/mean_batch stay untouched (tests pin those as a pure
    fit co-batching signal)."""
    calls, installed = [], []

    def runner(specs):
        calls.append(len(specs))
        return [(np.arange(2), None)] * len(specs), 0.001

    class _Fake:
        kind = "ask"
        __slots__ = ("bucket", "k_pad", "cand", "runner", "install")

        def __init__(self):
            self.bucket, self.k_pad = 64, 8
            self.cand = np.zeros((4, 2), np.float32)
            self.runner = runner

        @property
        def group_key(self):
            return (self.runner, self.bucket, self.k_pad,
                    tuple(self.cand.shape))

    ex = FitExecutor(workers=1)
    ex.MAX_LANES = 4        # pin the (normally dynamic) cap
    try:
        gate = threading.Event()
        ex.submit("hold", lambda: (gate.wait(5), False)[-1], PRIO_MISS)
        _wait(lambda: ex.backlog() == 0)
        for i in range(3):
            spec = _Fake()
            ex.submit(f"a{i}", BatchableAsk(
                lambda s=spec: FitLane(
                    s, lambda r, dt: installed.append(r))), PRIO_IDLE)
        gate.set()
        assert _wait(lambda: len(installed) == 3)
        assert calls == [3]
        snap = ex.snapshot()
        assert snap["batched_asks"] == 1 and snap["ask_lanes"] == 3
        assert snap["mean_ask_batch"] == pytest.approx(3.0)
        assert snap["batched"] == 0 and snap["lanes"] == 0
        assert snap["mean_batch"] == 0.0
    finally:
        ex.stop()


# ----------------------------------------------------- live service path
def _cfg(**kw):
    kw.setdefault("name", "batched-ask")
    kw.setdefault("optimizer", "gp")
    kw.setdefault("parallel", 4)
    kw.setdefault("space", _space())
    kw.setdefault("optimizer_options", {"n_init": 2, "fit_steps": 5,
                                        "warm_fit_steps": 5,
                                        "refit_every": 4})
    return ExperimentConfig(**kw)


def test_pump_routes_refills_through_batched_ask_plane():
    """A live gp experiment's queue refills must flow through the
    BatchableAsk path: ``batched_prefilled`` moves, the executor's
    ``batched_asks``/``ask_lanes`` counters move, and the queue still
    serves (hits) — the batched plane is the refill hot path, not a
    side channel."""
    client = LocalClient(tempfile.mkdtemp())
    exp = client.create_experiment(CreateExperiment(
        config=_cfg(budget=200, prefetch=6).to_json())).exp_id
    state = client._exps[exp]
    state.optimizer.prewarm(60, batch=4)
    rng = np.random.default_rng(0)
    try:
        for _ in range(24):
            s = client.suggest(exp, 1).suggestions[0]
            client.observe(ObserveRequest(
                exp, s.suggestion_id, s.assignment,
                _f(strip_internal(s.assignment))))
            time.sleep(0.01)

        def landed():
            st = client.status(exp)
            ex = st.pump.get("executor") or {}
            return (st.pump.get("batched_prefilled", 0) > 0
                    and ex.get("batched_asks", 0) >= 1)
        assert _wait(landed, timeout=60.0), \
            f"no batched refill landed: {client.status(exp).pump}"
        st = client.status(exp)
        assert st.pump["executor"]["ask_lanes"] >= 1
        assert st.pump["executor"]["mean_ask_batch"] >= 1.0
        assert _wait(
            lambda: client.status(exp).pump.get("hits", 0) > 0,
            timeout=30.0), "batched-refilled queue never served a hit"
    finally:
        client.stop(exp)
        client.close()


def test_miss_asks_bypass_gather_window():
    """Tentpole contract: miss serving keeps its exact inline ask —
    PRIO_MISS semantics unchanged.  With the executor's gather window
    pinned pathologically long, a dry-queue suggest must still return
    far sooner than the window: the miss never rides the batched
    plane's gather."""
    ex = pipeline.fit_executor()
    old = ex.GATHER_WINDOW
    ex.GATHER_WINDOW = 5.0
    client = LocalClient(tempfile.mkdtemp())
    try:
        exp = client.create_experiment(CreateExperiment(
            config=_cfg(budget=100, prefetch=2).to_json())).exp_id
        state = client._exps[exp]
        state.optimizer.prewarm(30, batch=4)
        rng = np.random.default_rng(0)
        # leave the random phase so misses hit the model path
        for _ in range(6):
            s = client.suggest(exp, 1).suggestions[0]
            client.observe(ObserveRequest(
                exp, s.suggestion_id, s.assignment, float(rng.normal())))
        misses0 = state.stats["misses"]
        # drain the queue, then time dry-queue suggests: every batched
        # refill is stuck waiting out the 5 s gather window, so these
        # can only be served by the inline miss path
        with state.lock:
            drained = [i.assignment for i in state.queue]
            state.queue = []
        for a in drained:
            with state.opt_lock:
                state.optimizer.forget(a)
        t0 = time.monotonic()
        s = client.suggest(exp, 1).suggestions[0]
        elapsed = time.monotonic() - t0
        assert elapsed < 3.0, \
            f"dry-queue suggest waited the gather window ({elapsed:.2f}s)"
        assert state.stats["misses"] > misses0
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(rng.normal())))
        client.stop(exp)
    finally:
        ex.GATHER_WINDOW = old
        client.close()


# ------------------------------------------------ contended live pumps
@pytest.mark.contention
@pytest.mark.skipif(not os.environ.get("REPRO_CONTENTION"),
                    reason="set REPRO_CONTENTION=1 (ci.sh tier-2)")
def test_sixteen_live_pumps_cobatch_refills():
    """16 live experiments' pumps refilling concurrently through the
    shared executor: refills must actually co-batch (mean_ask_batch
    > 1) while every experiment keeps serving."""
    client = LocalClient(tempfile.mkdtemp())
    exps = []
    try:
        for i in range(16):
            exp = client.create_experiment(CreateExperiment(
                config=_cfg(name=f"c{i}", budget=300,
                            prefetch=6).to_json())).exp_id
            exps.append(exp)
        client._exps[exps[0]].optimizer.prewarm(60, batch=4)
        ask0 = pipeline.fit_executor().snapshot()["ask_lanes"]

        def drive(exp, seed):
            rng = np.random.default_rng(seed)
            for _ in range(20):
                s = client.suggest(exp, 1).suggestions[0]
                client.observe(ObserveRequest(
                    exp, s.suggestion_id, s.assignment,
                    _f(strip_internal(s.assignment))))
                time.sleep(0.005)

        threads = [threading.Thread(target=drive, args=(e, i))
                   for i, e in enumerate(exps)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert _wait(
            lambda: pipeline.fit_executor().snapshot()["ask_lanes"]
            > ask0, timeout=60.0), "no batched ask reached the executor"
        assert _wait(
            lambda: pipeline.fit_executor().snapshot()["mean_ask_batch"]
            > 1.0, timeout=120.0), \
            f"refills never co-batched: {pipeline.fit_executor().snapshot()}"
        for exp in exps:
            assert client.status(exp).observed >= 20
    finally:
        for exp in exps:
            client.stop(exp)
        client.close()
