"""Batched wire protocol (API.md §Transport batching).

The transport-plane invariants under test (ISSUE 9 acceptance):
  * a batch redelivered after a mid-response connection kill applies
    exactly once — the server's dedupe window replays the recorded
    results instead of double-applying;
  * per-experiment op order survives interleaved flushes (the server
    applies each experiment's ops in client enqueue order);
  * only rung-crossing reports block for their real decision — the
    below-rung majority rides the batch with a synthetic CONTINUE;
  * a fenced incarnation's whole batch is rejected item-by-item and
    leaves ZERO log entries;
  * a ``FleetClient`` keeps one write-behind lane per owning shard and
    re-homes a single ``wrong_shard`` op without disturbing the rest
    of its batch.
"""
import os
import tempfile

import pytest

from repro.api import CreateExperiment, HTTPClient, serve_api
from repro.api.local import LocalClient
from repro.api.protocol import (BatchOp, BatchRequest, E_FENCED,
                                ObserveRequest, ReportRequest)
from repro.core import ExperimentConfig, Param, Space
from repro.core.store import Store
from repro.fleet import FleetClient, FleetManager


def chaos(fn):
    return pytest.mark.chaos(pytest.mark.skipif(
        not os.environ.get("REPRO_CHAOS"),
        reason="chaos fault injection (tier-2; set REPRO_CHAOS=1)")(fn))


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg_json(name, budget=64, **kw):
    kw.setdefault("optimizer", "random")
    kw.setdefault("space", _space())
    return dict(ExperimentConfig(name=name, budget=budget, **kw).to_json())


# ------------------------------------------------------------ exactly-once
@chaos
@pytest.mark.parametrize("retry_seed", [0, 1, 7])
def test_batch_replay_after_mid_response_kill_applies_exactly_once(
        retry_seed):
    """Kill the connection after the server has committed the batch but
    before the client reads the response: the idempotent resend must hit
    the dedupe window and replay, not double-apply."""
    root = tempfile.mkdtemp()
    server = serve_api(root).start()
    client = HTTPClient(server.url, batch=True, batch_deadline=60.0,
                        retry_seed=retry_seed)
    try:
        exp = client.create_experiment(CreateExperiment(
            config=_cfg_json("replay"))).exp_id
        # establish this thread's keep-alive conn, then arm a one-shot
        # fault on it: the next response is read one byte in, then the
        # connection dies — the server HAS applied the batch
        client.status(exp)
        conn = client._local.conn
        real = conn.getresponse
        armed = [True]

        def mid_response_kill():
            if armed[0]:
                armed[0] = False
                r = real()
                r.read(1)
                raise OSError("injected mid-response connection kill")
            return real()

        conn.getresponse = mid_response_kill
        n = 6
        for j in range(n):
            client.observe(ObserveRequest(
                exp, f"sid-{j:03d}", {"x": 0.5}, value=float(j)))
        client.flush()      # ships on this thread through the armed conn
        assert not armed[0], "injected fault never fired"
        assert client._wb.stats["replayed"] == 1
        assert client._wb.stats["batches"] == 1
        assert client._wb.stats["op_errors"] == 0
        records = Store(root).load_observation_records(exp)
        assert len(records) == n, "replayed batch must not double-apply"
        assert len({r["suggestion_id"] for r in records}) == n
        assert client.status(exp).observations == n
    finally:
        client.close()
        server.shutdown()


# ----------------------------------------------------------------- ordering
def test_per_experiment_op_order_survives_interleaved_flushes():
    """Small batch_max forces several wire batches; each experiment's
    metric stream must still land in enqueue order (seq-dense)."""
    root = tempfile.mkdtemp()
    server = serve_api(root).start()
    client = HTTPClient(server.url, batch=True, batch_max=4,
                        batch_deadline=60.0)
    try:
        exps = [client.create_experiment(CreateExperiment(
            config=_cfg_json(f"order-{i}"))).exp_id for i in range(2)]
        # first report per trial blocks (unknown rung) — prime the gate
        for e in exps:
            client.report(ReportRequest(e, "t0", 1, 0.1))
        # 12 interleaved riding reports per experiment across >= 6 batches
        for step in range(2, 14):
            for e in exps:
                client.report(ReportRequest(e, "t0", step, step / 100.0))
        client.flush()
        assert client._wb.stats["batches"] >= 3
        for e in exps:
            recs = Store(root).load_metrics(e)
            steps = [r["step"] for r in recs]
            assert steps == sorted(steps) == list(range(1, 14))
            seqs = [r["seq"] for r in recs]
            assert seqs == sorted(seqs)
    finally:
        client.close()
        server.shutdown()


# ------------------------------------------------------------ decision gate
def test_rung_crossing_report_blocks_while_below_rung_reports_ride():
    root = tempfile.mkdtemp()
    server = serve_api(root).start()
    client = HTTPClient(server.url, batch=True, batch_deadline=60.0)
    try:
        exp = client.create_experiment(CreateExperiment(
            config=_cfg_json("gate", early_stop={"min_steps": 1,
                                                 "eta": 3}))).exp_id
        # first report of a trial: rung unknown -> blocks for the real
        # decision (a real decision carries the server's stream seq)
        d1 = client.report(ReportRequest(exp, "t0", 1, 0.5))
        assert d1.seq != 0
        nr = d1.next_rung
        assert nr is not None and nr > 1
        # strictly below the next rung: rides the batch with a synthetic
        # CONTINUE (seq=0 marks it client-side)
        for step in range(2, nr):
            d = client.report(ReportRequest(exp, "t0", step, 0.5))
            assert d.seq == 0 and d.decision == "continue"
        assert client._wb.depth() == max(0, nr - 2)
        # at the rung: blocks again — the queue drains first, then the
        # plain call returns the server's decision
        dr = client.report(ReportRequest(exp, "t0", nr, 0.5))
        assert dr.seq != 0
        assert client._wb.depth() == 0
        recs = Store(root).load_metrics(exp)
        assert [r["step"] for r in recs] == list(range(1, nr + 1))
    finally:
        client.close()
        server.shutdown()


# ---------------------------------------------------------------- fencing
def test_fenced_zombie_batch_rejected_item_by_item_with_zero_log_entries():
    root = tempfile.mkdtemp()
    zombie = LocalClient(root)
    eid = zombie.create_experiment(CreateExperiment(
        config=_cfg_json("fence-batch", budget=6), exp_id="exp-fence-batch",
        epoch=[1, 1])).exp_id
    held = zombie.suggest(eid, 2).suggestions
    owner = LocalClient(root)
    owner.create_experiment(CreateExperiment(config={}, exp_id=eid,
                                             epoch=[1, 2]))
    # the zombie heals and flushes a whole mixed batch: every op answers
    # typed fenced, none is applied, nothing reaches the log
    req = BatchRequest("bz-fence-1", [
        BatchOp(0, "observe", ObserveRequest(
            eid, held[0].suggestion_id, held[0].assignment,
            value=0.9).to_json()),
        BatchOp(1, "report", ReportRequest(eid, "t0", 1, 0.9).to_json()),
        BatchOp(2, "observe", ObserveRequest(
            eid, held[1].suggestion_id, held[1].assignment,
            value=0.8).to_json()),
        BatchOp(3, "release", {"exp_id": eid,
                               "suggestion_id": held[1].suggestion_id}),
    ])
    resp = zombie.apply_batch(req)
    assert len(resp.results) == 4
    for r in resp.results:
        assert not r.ok and r.error["code"] == E_FENCED
    assert owner.store.load_observation_records(eid) == []
    assert owner.store.load_metrics(eid) == []
    # the exact same batch replayed answers the recorded fenced results
    again = zombie.apply_batch(req)
    assert again.replayed
    assert [r.error["code"] for r in again.results] == [E_FENCED] * 4


# ------------------------------------------------------------------- fleet
@chaos
def test_fleet_client_keeps_one_lane_per_shard_and_rehomes_wrong_shard():
    root = tempfile.mkdtemp()
    manager = FleetManager()
    for i in range(2):
        manager.add_shard(LocalClient(root), shard_id=f"shard-{i}")
    client = FleetClient(manager, heartbeat=False, batch=True,
                         batch_deadline=60.0)
    try:
        # pick (by non-destructive ring simulation) one experiment that a
        # late-joining third shard would take over, and one on the OTHER
        # current owner that stays put
        ring = manager.ring
        moved = next(f"exp-lane-{i:03d}" for i in range(256)
                     if ring.moved_by_adding("shard-late",
                                             [f"exp-lane-{i:03d}"]))
        kept = next(f"exp-keep-{i:03d}" for i in range(256)
                    if ring.owner(f"exp-keep-{i:03d}") != ring.owner(moved)
                    and not ring.moved_by_adding("shard-late",
                                                 [f"exp-keep-{i:03d}"]))
        eids, owners = [moved, kept], {ring.owner(moved), ring.owner(kept)}
        # two experiments on two different owners -> two write-behind
        # lanes (blocking create/suggest first: they drain the queue)
        sugg = {}
        for eid in eids:
            client.create_experiment(CreateExperiment(
                config=_cfg_json(eid, budget=8), exp_id=eid))
            sugg[eid] = client.suggest(eid, 1).suggestions[0]
        for eid in eids:
            s = sugg[eid]
            client.observe(ObserveRequest(eid, s.suggestion_id,
                                          s.assignment, value=0.5))
        with client._wb._cv:
            lanes = [l for l, q in client._wb._lanes.items() if q]
        assert sorted(lanes) == sorted(owners)
        client.flush()
        for eid in eids:
            assert client.status(eid).observations == 1
        assert client._holdings == {}

        # enqueue an op for the doomed experiment on its (about to be
        # stale) owner lane, then add the shard: the per-op wrong_shard
        # answer must re-home JUST that op while its batch-mates land
        # where they were
        sm = client.suggest(moved, 1).suggestions[0]
        sk = client.suggest(kept, 1).suggestions[0]
        client.observe(ObserveRequest(moved, sm.suggestion_id,
                                      sm.assignment, value=0.7))
        client.observe(ObserveRequest(kept, sk.suggestion_id,
                                      sk.assignment, value=0.7))
        manager.add_shard(LocalClient(root), shard_id="shard-late")
        client.flush()      # stale lane -> wrong_shard -> re-home -> apply
        assert client.status(moved).observations == 2
        assert client.status(kept).observations == 2
        assert client._wb.stats["op_errors"] == 0
        assert client._holdings == {}
        assert client._owner(moved) == "shard-late"
    finally:
        client.close()


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-v"] + sys.argv[1:]))
