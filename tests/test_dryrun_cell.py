"""One real dry-run cell end-to-end in a subprocess (512 fake devices)."""
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "dry"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-125m", "--shape", "long_500k", "--mesh", "pod",
         "--out", str(out)],
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=600, cwd=str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(
        (out / "xlstm-125m__long_500k__16x16.json").read_text())
    assert rec["ok"] and rec["n_devices"] == 256
    assert rec["roofline"]["t_compute_s"] >= 0
