"""ISSUE 2 hot-path contracts: bucketed static shapes (no recompile within
a bucket), rank-1 constant-liar updates vs full refit, one hyperparameter
fit per ask(n) batch, keyed pending-lie retirement."""
import numpy as np
import pytest

from repro.core.space import Param, Space
from repro.core.suggest import Observation, make_optimizer
from repro.core.suggest import gp
from repro.core.suggest.bayesopt import LIE_KEY


def _space():
    return Space([Param("x", "double", 0, 1),
                  Param("y", "double", 1e-4, 1e0, log=True)])


def _f(a):
    return -((a["x"] - 0.62) ** 2 + (np.log10(a["y"]) + 2.0) ** 2)


def _clean(a):
    return {k: v for k, v in a.items() if not k.startswith("__")}


# ------------------------------------------------------------------ buckets
def test_bucket_size_powers_of_two():
    assert gp.bucket_size(1) == gp.MIN_BUCKET
    assert gp.bucket_size(gp.MIN_BUCKET) == gp.MIN_BUCKET
    assert gp.bucket_size(gp.MIN_BUCKET + 1) == 2 * gp.MIN_BUCKET
    assert gp.bucket_size(150) == 256


def test_padding_does_not_change_the_posterior():
    """Masked MLL/posterior must be invariant to the bucket size — the
    identity padding block contributes nothing."""
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(24, 2))
    y = np.sin(4 * x[:, 0]) + 0.5 * x[:, 1]
    q = rng.uniform(size=(16, 2)).astype(np.float32)
    p_small = gp.fit_gp(x, y, steps=80)                # bucket 32
    p_big = gp.fit_gp(x, y, steps=80, bucket=128)
    mu1, sd1 = map(np.asarray, gp.predict(p_small, q))
    mu2, sd2 = map(np.asarray, gp.predict(p_big, q))
    np.testing.assert_allclose(mu1, mu2, atol=5e-4)
    np.testing.assert_allclose(sd1, sd2, atol=5e-4)


def test_no_recompile_within_bucket():
    """A 10→150-observation sweep may compile each jitted GP function at
    most once per shape bucket (the whole point of padding)."""
    rng = np.random.default_rng(1)
    x = rng.uniform(size=(150, 2))
    y = np.sin(5 * x[:, 0]) + x[:, 1] + 0.05 * rng.normal(size=150)
    q = rng.uniform(size=(8, 2)).astype(np.float32)
    sizes = list(range(10, 151, 7))
    buckets = {gp.bucket_size(n) for n in sizes}

    before_fit = gp._fit._cache_size()
    before_pred = gp.predict._cache_size()
    before_ei = gp.expected_improvement._cache_size()
    post = None
    for n in sizes:
        post = gp.fit_gp(x[:n], y[:n], steps=25)
        gp.predict(post, q)
        gp.expected_improvement(post, q, np.float32(y[:n].max()))
    assert gp._fit._cache_size() - before_fit <= len(buckets)
    assert gp.predict._cache_size() - before_pred <= len(buckets)
    assert gp.expected_improvement._cache_size() - before_ei <= len(buckets)


def test_select_batch_compiles_once_per_padded_k():
    """Varying ask sizes must share compiles: the q-EI scan length is
    padded to a power of two, so k in 1..8 costs at most 4 compiles per
    bucket (k_pad in {1,2,4,8})."""
    rng = np.random.default_rng(4)
    x = rng.uniform(size=(20, 2))
    y = np.sin(5 * x[:, 0]) + x[:, 1]
    cand = rng.uniform(size=(64, 2)).astype(np.float32)
    post = gp.fit_gp(x, y, steps=25, bucket=64)   # room for all the lies
    before = gp._select_scan._cache_size()
    for k in (1, 2, 3, 4, 5, 6, 7, 8):
        picks, _ = gp.select_batch(post, cand, np.float32(y.max()), k)
        assert len(picks) == k
        assert len(set(np.asarray(picks).tolist())) == k
    assert gp._select_scan._cache_size() - before <= 4


# ------------------------------------------------------------- rank-1 path
def test_rank1_append_matches_full_cholesky():
    """Posterior grown by rank-1 appends must agree with the from-scratch
    Cholesky at the same hyperparameters to <=1e-3 relative error."""
    rng = np.random.default_rng(2)
    x = rng.uniform(size=(28, 2))
    y = np.sin(4 * x[:, 0]) + 0.5 * x[:, 1] + 0.1 * rng.normal(size=28)
    post = gp.fit_gp(x[:20], y[:20], steps=120, bucket=32)
    inc = post
    for i in range(20, 28):
        inc = gp.append_point(inc, np.asarray(x[i], np.float32),
                              np.float32(y[i]))
    ref = gp.make_posterior(post.params, x, y, y_mean=post.y_mean,
                            y_std=post.y_std, bucket=32)
    q = rng.uniform(size=(64, 2)).astype(np.float32)
    mu_i, sd_i = map(np.asarray, gp.predict(inc, q))
    mu_r, sd_r = map(np.asarray, gp.predict(ref, q))
    assert np.linalg.norm(mu_i - mu_r) / np.linalg.norm(mu_r) <= 1e-3
    assert np.linalg.norm(sd_i - sd_r) / np.linalg.norm(sd_r) <= 1e-3


def test_append_lie_pins_posterior_mean():
    rng = np.random.default_rng(3)
    x = rng.uniform(size=(16, 2))
    y = np.sin(3 * x[:, 0])
    post = gp.fit_gp(x, y, steps=120, bucket=32)
    xq = np.asarray([[0.3, 0.7]], np.float32)
    mu_before, sd_before = map(np.asarray, gp.predict(post, xq))
    lied = gp.append_lie(post, xq[0])
    mu_after, sd_after = map(np.asarray, gp.predict(lied, xq))
    # mean unchanged (the lie *is* the mean), uncertainty collapses
    assert abs(float(mu_after[0] - mu_before[0])) < 5e-3
    assert float(sd_after[0]) < float(sd_before[0])


# --------------------------------------------------------------- ask batch
def test_ask_batch_distinct_points_single_fit(monkeypatch):
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=4, fit_steps=60)
    for _ in range(2):
        asks = opt.ask(4)
        opt.tell([Observation(a, _f(_clean(a))) for a in asks])

    calls = []
    real_fit = gp.fit_gp
    monkeypatch.setattr(gp, "fit_gp", lambda *a, **kw:
                        calls.append(kw.get("steps")) or real_fit(*a, **kw))
    batch = opt.ask(6)
    assert len(calls) == 1, "ask(n) must do exactly one hyperparameter fit"
    assert len(batch) == 6
    pts = np.array([space.to_unit(_clean(a)) for a in batch])
    d = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
    np.fill_diagonal(d, 1.0)
    assert d.min() > 1e-4, "batch points must be distinct"


def test_warm_start_uses_fewer_steps(monkeypatch):
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=4, fit_steps=80,
                         warm_fit_steps=20, refit_every=1)
    steps_seen = []
    real_fit = gp.fit_gp
    monkeypatch.setattr(gp, "fit_gp", lambda *a, **kw:
                        steps_seen.append(kw.get("steps"))
                        or real_fit(*a, **kw))
    for _ in range(3):
        asks = opt.ask(3)
        opt.tell([Observation(a, _f(_clean(a))) for a in asks])
    opt.ask(1)
    assert steps_seen[0] == 80, "cold fit runs the full step budget"
    assert all(s == 20 for s in steps_seen[1:]), \
        "warm-started fits run the reduced step budget"


# ------------------------------------------------------------ pending lies
def test_pending_lies_retired_by_key_not_coordinates():
    """Two near-identical pending suggestions (speculative twins) must
    retire independently — coordinate matching would pop the wrong one."""
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=2)
    u = np.array([0.5, 0.5])
    opt._pending = {"lie00001": u.copy(), "lie00002": u.copy()}
    a = space.from_unit(u)
    a[LIE_KEY] = "lie00002"
    opt.tell([Observation(a, 1.0)])
    assert "lie00001" in opt._pending
    assert "lie00002" not in opt._pending


def test_pending_lie_fallback_matches_legacy_observations():
    """Observations without a lie token (old logs) still retire pending
    lies by coordinate."""
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=2)
    asks = opt.ask(2)
    assert len(opt._pending) == 2
    legacy = Observation(_clean(asks[0]), 0.5)     # token stripped
    opt.tell([legacy])
    assert len(opt._pending) == 1


def test_ask_observe_loop_keeps_pending_bounded():
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=4)
    for _ in range(6):
        asks = opt.ask(3)
        opt.tell([Observation(a, _f(_clean(a))) for a in asks])
    assert not opt._pending, "observed suggestions must retire their lies"


def test_recondition_between_fits_drops_stale_lies(monkeypatch):
    """With refit_every>1, observes between hyperparameter fits rebuild
    the posterior at the current hyperparameters (no Adam) and must not
    condition on both a retired lie and its real observation."""
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=4, fit_steps=60,
                         refit_every=100)    # hyperfit effectively once
    for _ in range(2):
        asks = opt.ask(4)
        opt.tell([Observation(a, _f(_clean(a))) for a in asks])
    opt.ask(2)                               # one fit happens here
    calls = []
    real_fit = gp.fit_gp
    monkeypatch.setattr(gp, "fit_gp", lambda *a, **kw:
                        calls.append(1) or real_fit(*a, **kw))
    for _ in range(3):
        asks = opt.ask(2)
        opt.tell([Observation(a, _f(_clean(a))) for a in asks])
    assert not calls, "between refits asks must recondition, not refit"
    # posterior rows == real observations + pending lies, no stale lies
    asks = opt.ask(1)
    assert opt._n_in_post == len(opt._ys) + len(opt._pending)
