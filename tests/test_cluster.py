"""Cluster: pools, allocation, elasticity, failures."""
import pytest

from repro.core.cluster import Cluster, ClusterConfig, PoolConfig


def _cluster():
    return Cluster(ClusterConfig("c", pools=[
        PoolConfig("cpu", "cpu", chips=8),
        PoolConfig("tpu", "tpu", chips=16, min_chips=4, max_chips=32,
                   chips_per_node=4)]))


def test_allocate_release():
    c = _cluster()
    leases = [c.allocate("tpu", 4) for _ in range(4)]
    assert all(l is not None for l in leases)
    assert c.allocate("tpu", 4) is None            # full
    c.release(leases[0])
    assert c.allocate("tpu", 4) is not None


def test_heterogeneous_pools_isolated():
    c = _cluster()
    assert c.allocate("cpu", 8) is not None
    assert c.allocate("cpu", 1) is None
    assert c.allocate("tpu", 8) is not None        # unaffected


def test_unknown_pool_raises():
    with pytest.raises(KeyError):
        _cluster().allocate("gpu", 1)


def test_elastic_scale_clamped():
    c = _cluster()
    assert c.scale("tpu", 64) == 32                # max_chips
    assert c.scale("tpu", 0) == 4                  # min_chips
    st = c.status()
    assert st["pools"]["tpu"]["chips"] == 4


def test_fail_nodes_revokes_leases():
    c = _cluster()
    revoked_cb = []
    l1 = c.allocate("tpu", 12,
                    on_revoke=lambda l: revoked_cb.append(l.lease_id))
    assert c.status()["pools"]["tpu"]["free"] == 4
    victims = c.fail_nodes("tpu", 2)               # lose 8 chips: 4 free + 4
    assert victims and victims[0].revoked
    assert revoked_cb == [l1.lease_id]
    # released revoked lease does not return capacity
    c.release(l1)
    assert c.status()["pools"]["tpu"]["free"] == 0
