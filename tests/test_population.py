"""Population (vmap) training == sequential training, exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.vmap_trials import PopulationTrainer
from repro.models import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _data(cfg):
    def it(t):
        r = np.random.default_rng(1000 + t)
        return {"tokens": jnp.asarray(
                    r.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
                "labels": jnp.asarray(
                    r.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    return it


def test_population_equals_sequential():
    cfg = get_config("granite-8b").reduced(n_layers=2)
    trainer = PopulationTrainer(cfg, AdamWConfig(clip_norm=1.0))
    assigns = [{"lr": 1e-3, "weight_decay": 0.0, "seed": 0},
               {"lr": 3e-3, "weight_decay": 0.1, "seed": 1}]
    pop = trainer.train(assigns, _data(cfg), steps=6, eval_last=2)

    model = LM(cfg)
    ocfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    for i, a in enumerate(assigns):
        params = model.init(jax.random.key(a["seed"]))
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch, lr, wd):
            (loss, _), g = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(params)
            newp, newopt, _ = adamw_update(g, opt, params, ocfg, lr)
            newp = jax.tree.map(
                lambda np_, p_: (np_.astype(jnp.float32)
                                 - lr * wd * p_.astype(jnp.float32)
                                 ).astype(np_.dtype), newp, params)
            return newp, newopt, loss

        tail = []
        for t in range(6):
            params, opt, loss = step(params, opt, _data(cfg)(t),
                                     a["lr"], a["weight_decay"])
            if t >= 4:
                tail.append(float(loss))
        assert abs(pop[i] - np.mean(tail)) < 1e-5


def test_population_distinct_seeds_distinct_params():
    cfg = get_config("xlstm-125m").reduced()
    trainer = PopulationTrainer(cfg)
    st = trainer.init_states([{"seed": 0}, {"seed": 1}])
    w = jax.tree.leaves(st["params"])[0]
    assert not np.allclose(np.asarray(w[0]), np.asarray(w[1]))
