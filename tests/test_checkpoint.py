"""Checkpoint manager: roundtrip, atomicity, retention, resume."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _state(seed):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "groups": [{"0": jnp.arange(6.0)}]},
            "opt": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    st = _state(0)
    mgr.save(10, st, {"loss": 1.5})
    got, meta = mgr.restore(jax.tree.map(np.zeros_like, st))
    assert meta["step"] == 10 and meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 5, 9):
        mgr.save(s, _state(s))
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # step 1 collected


def test_async_write_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    st = _state(3)
    mgr.save(2, st)
    mgr.wait()
    got, meta = mgr.restore(jax.tree.map(np.zeros_like, st))
    assert meta["step"] == 2


def test_incomplete_tmp_dir_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(4, _state(1))
    (pathlib.Path(tmp_path) / ".tmp-9").mkdir()      # simulated crash
    (pathlib.Path(tmp_path) / "step_00000009").mkdir()  # no state.npz
    assert mgr.latest_step() == 4


def test_shape_mismatch_rejected(tmp_path):
    p = pathlib.Path(tmp_path) / "x.npz"
    save_pytree({"w": np.zeros((2, 2))}, p)
    with pytest.raises(ValueError):
        load_pytree({"w": np.zeros((3, 3))}, p)
