"""Suggestion-pipeline correctness (ISSUE 4): prefetch pump, queue-miss
coalescing, K-observation staleness invalidation, and drain semantics on
``stop()`` / service restart.

The multi-client contention stress tests are marked ``contention`` and
skipped in tier-1 (they hammer the service with thread fleets); CI runs
them behind the tier-2 gate via ``REPRO_CONTENTION=1`` (scripts/ci.sh).
"""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.api import (CreateExperiment, HTTPClient, LocalClient,
                       ObserveRequest, serve_api)
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space

def contention(fn):
    """Marks a multi-client stress test: tier-2 only (scripts/ci.sh sets
    REPRO_CONTENTION=1 and selects ``-m contention``)."""
    fn = pytest.mark.contention(fn)
    return pytest.mark.skipif(
        not os.environ.get("REPRO_CONTENTION"),
        reason="contention stress (tier-2; set REPRO_CONTENTION=1)")(fn)


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg(**kw):
    kw.setdefault("name", "pipe")
    kw.setdefault("optimizer", "random")
    kw.setdefault("parallel", 4)
    kw.setdefault("space", _space())
    return ExperimentConfig(**kw)


def _create(client, cfg, exp_id=None):
    return client.create_experiment(
        CreateExperiment(config=cfg.to_json(), exp_id=exp_id))


def _wait(predicate, timeout=10.0, every=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(every)
    return predicate()


def _fill(client, exp, depth, timeout=10.0):
    assert _wait(lambda: client.status(exp).prefetched >= depth, timeout), \
        f"pump never filled the queue: {client.status(exp).pump}"


# -------------------------------------------------------------- fast paths
def test_suggest_pops_from_warm_queue():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=50, prefetch=6)).exp_id
    _fill(client, exp, 6)
    batch = client.suggest(exp, 3)
    assert len(batch) == 3
    st = client.status(exp)
    assert st.pending == 3
    assert st.pump["hits"] == 3 and st.pump["misses"] == 0
    ids = {s.suggestion_id for s in batch.suggestions}
    assert len(ids) == 3


def test_pump_respects_budget_headroom():
    """The queue is speculation, not budget: prefetched suggestions are
    not pending, and queue+pending+observed never oversubscribe."""
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=4, prefetch=16)).exp_id
    _fill(client, exp, 4)
    st = client.status(exp)
    assert st.prefetched == 4, "queue must stop at budget headroom"
    b = client.suggest(exp, 10)
    assert len(b) == 4 and b.remaining == 0
    assert len(client.suggest(exp, 1)) == 0
    st = client.status(exp)
    assert st.pending == 4 and st.observations == 0


def test_concurrent_suggest_unique_ids_and_budget():
    """No duplicate suggestion_ids under concurrent pipelined suggest;
    observed + pending never exceeds the budget."""
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=48, prefetch=8)).exp_id
    out, lock = [], threading.Lock()

    def worker():
        got = []
        for _ in range(3):
            got.extend(client.suggest(exp, 2).suggestions)
        with lock:
            out.extend(got)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ids = [s.suggestion_id for s in out]
    assert len(ids) == 48 and len(set(ids)) == 48
    st = client.status(exp)
    assert st.observations + st.pending <= 48


def test_queue_misses_coalesce_into_batched_ask():
    """Concurrent queue misses must be served by few batched asks, not N
    serialized ones (cross-scheduler request coalescing)."""
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=64, prefetch=0)).exp_id
    state = client._exps[exp]
    calls = []
    orig = state.optimizer.ask

    def slow_ask(n):
        calls.append(n)
        time.sleep(0.05)        # model cost: concurrent misses pile up
        return orig(n)

    state.optimizer.ask = slow_ask
    out, lock = [], threading.Lock()

    def worker():
        got = client.suggest(exp, 1).suggestions
        with lock:
            out.extend(got)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ids = [s.suggestion_id for s in out]
    assert len(ids) == 8 and len(set(ids)) == 8
    assert len(calls) < 8, f"misses did not coalesce: {calls}"
    assert sum(calls) == 8, "coalesced asks must cover every miss exactly"
    assert client.status(exp).pump["coalesced"] > 0


# -------------------------------------------------------------- staleness
def test_stale_prefetched_suggestions_never_served():
    """A queued suggestion computed K observations ago is invalidated at
    pop time — the model has since learned; serving it would waste a
    budget slot on a known-bad region."""
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=100, prefetch=4, staleness=2)).exp_id
    _fill(client, exp, 4)
    state = client._exps[exp]
    with state.lock:
        stale_assignments = [i.assignment for i in state.queue]
    # K=2 new observations arrive (untracked ids are tolerated)
    for i in range(2):
        client.observe(ObserveRequest(exp, f"s-ext{i}", {"x": 0.5 + i / 10},
                                      float(i)))
    batch = client.suggest(exp, 4)
    assert len(batch) == 4
    served = [s.assignment for s in batch.suggestions]
    for a in served:
        assert a not in stale_assignments, \
            "served a suggestion past its staleness bound"
    st = client.status(exp)
    assert st.pump["invalidated"] >= 1
    # pending accounting balanced: only the served batch is pending
    assert st.pending == 4


def test_invalidation_retires_constant_liar_lies():
    """Invalidated queue entries must release their GP lies — a leaked lie
    permanently suppresses EI around a point that will never be observed."""
    client = LocalClient(tempfile.mkdtemp())
    cfg = _cfg(budget=100, optimizer="gp", prefetch=3, staleness=1,
               optimizer_options={"n_init": 2, "fit_steps": 10,
                                  "warm_fit_steps": 5})
    exp = _create(client, cfg).exp_id
    for i in range(3):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    _wait(lambda: client.status(exp).prefetched >= 1)
    # every queued item is stale after one more observation (K=1)
    client.observe(ObserveRequest(exp, "s-ext", {"x": 0.77}, 9.0))
    client.suggest(exp, 2)
    client.stop(exp)
    state = client._exps[exp]
    assert not state.optimizer._pending, \
        f"leaked lies: {state.optimizer._pending}"
    assert state.queue == [] and state.pending == {}


# ------------------------------------------------------------------- drain
def test_stop_drains_pump_queue_and_pending():
    client = LocalClient(tempfile.mkdtemp())
    cfg = _cfg(budget=60, optimizer="gp", prefetch=4,
               optimizer_options={"n_init": 2, "fit_steps": 10,
                                  "warm_fit_steps": 5})
    exp = _create(client, cfg).exp_id
    for i in range(3):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    _wait(lambda: client.status(exp).prefetched >= 1)
    client.suggest(exp, 1)          # leave one pending too
    client.stop(exp)
    state = client._exps[exp]
    assert not (state.pump and state.pump.alive), "pump must be dead"
    assert state.queue == [] and state.pending == {}
    assert not state.optimizer._pending, "stop must retire every lie"
    assert len(client.suggest(exp, 2)) == 0, \
        "a stopped experiment must never serve (queued or fresh)"


def test_budget_completion_winds_pump_down():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=3, prefetch=4)).exp_id
    batch = client.suggest(exp, 3)
    for i, s in enumerate(batch.suggestions):
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    st = client.status(exp)         # terminal reconcile point
    assert st.state == "complete" and st.observations == 3
    assert st.prefetched == 0, "complete experiments hold no speculation"
    assert _wait(lambda: not client._exps[exp].pump.alive, 5.0), \
        "pump must exit once the budget is spent"


def test_pump_restarts_across_service_restart_resume():
    root = tempfile.mkdtemp()
    c1 = LocalClient(root)
    cfg = _cfg(budget=40, prefetch=4)
    exp = _create(c1, cfg).exp_id
    _fill(c1, exp, 4)
    for i in range(3):
        s = c1.suggest(exp, 1).suggestions[0]
        c1.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                  float(i)))
    c1.close()
    assert not c1._exps[exp].pump.alive

    # "restarted" service over the same store
    c2 = LocalClient(root)
    resp = _create(c2, cfg, exp_id=exp)
    assert resp.resumed and resp.observations == 3
    _fill(c2, exp, 4)
    st = c2.status(exp)
    assert st.pump["alive"] and st.prefetched >= 4
    batch = c2.suggest(exp, 2)
    assert len(batch) == 2
    # replay stayed exact: in-memory history == log, no double-fold
    assert len(c2._exps[exp].optimizer.history) == 3
    c2.stop(exp)


def test_close_then_suggest_restarts_pump():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=30, prefetch=3)).exp_id
    _fill(client, exp, 3)
    client.close()
    assert not client._exps[exp].pump.alive
    assert len(client.suggest(exp, 1)) == 1      # restarts the pump
    assert _wait(lambda: client.status(exp).pump["alive"], 5.0)


def test_status_reports_pipeline_fields_over_http():
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        client = HTTPClient(server.url)
        exp = _create(client, _cfg(budget=20, prefetch=3)).exp_id
        _fill(client, exp, 3)
        st = client.status(exp)
        assert st.prefetched == 3
        assert st.pump["alive"] and st.pump["depth"] == 3
    finally:
        server.shutdown()
    # server shutdown drains the backend's pumps
    state = server.backend._exps[exp]
    assert not state.pump.alive


# -------------------------------------------------------------- contention
@contention
def test_contended_suggest_gp_8_clients():
    """8 threads in a suggest/observe loop against one GP experiment:
    every suggestion unique, budget never oversubscribed, and the pipeline
    actually absorbs the load (queue hits or coalesced misses)."""
    client = LocalClient(tempfile.mkdtemp())
    cfg = _cfg(budget=400, parallel=8, optimizer="gp",
               optimizer_options={"n_init": 4, "fit_steps": 20,
                                  "warm_fit_steps": 10})
    exp = _create(client, cfg).exp_id
    rng = np.random.default_rng(0)
    for i in range(10):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(rng.normal())))
    out, lock = [], threading.Lock()

    def worker(seed):
        r = np.random.default_rng(seed)
        got = []
        for _ in range(6):
            batch = client.suggest(exp, 1)
            for s in batch.suggestions:
                got.append(s.suggestion_id)
                client.observe(ObserveRequest(
                    exp, s.suggestion_id, s.assignment, float(r.normal())))
            time.sleep(0.02)
        with lock:
            out.extend(got)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(out) == len(set(out)), "duplicate suggestion ids"
    assert len(out) == 48
    st = client.status(exp)
    assert st.observations + st.pending <= 400
    assert st.pump["hits"] + st.pump["misses"] >= 48
    client.stop(exp)
    assert not client._exps[exp].optimizer._pending


@contention
def test_contended_two_http_workers_share_budget():
    """Two HTTP worker fleets over one pipelined experiment: global
    budget exact, no duplicates across processes' request streams."""
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        cfg = _cfg(budget=60, parallel=4, prefetch=8)
        exp = _create(HTTPClient(server.url), cfg).exp_id
        seen, lock = [], threading.Lock()

        def fleet():
            cl = HTTPClient(server.url)
            while True:
                batch = cl.suggest(exp, 2)
                if not batch.suggestions:
                    if cl.status(exp).observations >= 60:
                        return
                    time.sleep(0.005)
                    continue
                for s in batch.suggestions:
                    with lock:
                        seen.append(s.suggestion_id)
                    cl.observe(ObserveRequest(exp, s.suggestion_id,
                                              s.assignment, 0.5))

        threads = [threading.Thread(target=fleet) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert len(seen) == 60 and len(set(seen)) == 60
        st = HTTPClient(server.url).status(exp)
        assert st.observations == 60 and st.pending == 0
    finally:
        server.shutdown()
