"""Scheduler semantics: parallelism bound, retry, ASHA, stragglers,
admission control, preemption requeue, delete."""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import (Cluster, ClusterConfig, ExperimentConfig, Orchestrator,
                        Param, Resources, Space)
from repro.core.faults import ChaosMonkey, FaultPolicy, wrap_trial


def _orch():
    return Orchestrator(tempfile.mkdtemp())


def _space():
    return Space([Param("x", "double", 0, 1)])


def test_parallel_bound_respected():
    orch = _orch()
    in_flight, peak = [0], [0]
    lock = threading.Lock()

    def trial(a, ctx):
        with lock:
            in_flight[0] += 1
            peak[0] = max(peak[0], in_flight[0])
        time.sleep(0.03)
        with lock:
            in_flight[0] -= 1
        return a["x"]

    cfg = ExperimentConfig(name="p", budget=12, parallel=3,
                           optimizer="random", space=_space())
    orch.run(cfg, trial_fn=trial)
    assert peak[0] <= 3
    assert peak[0] >= 2          # actually ran concurrently


def test_crash_retry_then_fail():
    orch = _orch()
    attempts = {}

    def trial(a, ctx):
        key = round(a["x"], 6)
        attempts[key] = attempts.get(key, 0) + 1
        raise RuntimeError("boom")

    cfg = ExperimentConfig(name="c", budget=4, parallel=2, optimizer="random",
                           space=_space(), max_retries=1)
    exp = orch.run(cfg, trial_fn=trial)
    st = orch.status(exp)
    assert st["failures"] == 4
    assert all(v == 2 for v in attempts.values())   # retried exactly once


def test_admission_control_queues_when_full():
    orch = _orch()
    orch.cluster_create({"cluster_name": "small",
                         "pools": [{"name": "tpu", "resource": "tpu",
                                    "chips": 4}]})

    def trial(a, ctx):
        time.sleep(0.02)
        return 1.0

    cfg = ExperimentConfig(name="a", budget=6, parallel=4, optimizer="random",
                           space=_space(),
                           resources=Resources(pool="tpu", chips=4))
    exp = orch.run(cfg, trial_fn=trial, cluster="small")
    st = orch.status(exp)
    assert st["observations"] == 6     # all ran, just serialized by capacity
    c = orch.cluster_status("small")
    assert c["pools"]["tpu"]["free"] == 4


def test_asha_prunes():
    orch = _orch()
    stopped = []

    def trial(a, ctx):
        v = a["x"]
        for step in (1, 3, 9):
            ctx.report(step, v)
            time.sleep(0.002)
        return v

    cfg = ExperimentConfig(name="asha", budget=18, parallel=6,
                           optimizer="random", space=_space(),
                           early_stop={"min_steps": 1, "eta": 3})
    exp = orch.run(cfg, trial_fn=trial)
    obs = orch.store.load_observations(exp)
    pruned = [o for o in obs if o.metadata.get("pruned")]
    full = [o for o in obs if not o.metadata.get("pruned") and not o.failed]
    assert pruned, "ASHA should prune someone"
    # survivors are better on average than the pruned
    assert (np.mean([o.value for o in full])
            > np.mean([o.value for o in pruned]))


def test_straggler_speculation_wins():
    orch = _orch()
    calls = {"n": 0}
    lock = threading.Lock()

    def trial(a, ctx):
        with lock:
            calls["n"] += 1
            first = calls["n"] <= 4
        # trials 1-4 are fast; the 5th's FIRST attempt hangs (straggler)
        if not first and not ctx.trial_id.endswith("-spec1"):
            for _ in range(400):
                time.sleep(0.01)
                ctx.report(1, 0.0)    # lets the loser get cancelled
        time.sleep(0.01)
        return a["x"]

    cfg = ExperimentConfig(name="s", budget=5, parallel=2, optimizer="random",
                           space=_space(), straggler_factor=3.0,
                           max_retries=0)
    t0 = time.time()
    exp = orch.run(cfg, trial_fn=trial)
    took = time.time() - t0
    st = orch.status(exp)
    assert st["observations"] == 5
    assert took < 3.0, f"speculation should beat the 4s straggler ({took=})"


def test_delete_stops_execution():
    orch = _orch()
    started = threading.Event()

    def trial(a, ctx):
        started.set()
        for _ in range(1000):
            time.sleep(0.005)
            ctx.report(1, 0.0)
        return 1.0

    cfg = ExperimentConfig(name="d", budget=50, parallel=2,
                           optimizer="random", space=_space())
    exp = orch.run(cfg, trial_fn=trial, background=True)
    assert started.wait(5.0)
    orch.delete(exp)
    orch.wait(exp, timeout=10)
    assert orch.status(exp).get("state") in ("deleted", "stopped")


def test_node_failure_requeues_and_completes():
    orch = _orch()
    orch.cluster_create({"cluster_name": "chaos",
                         "pools": [{"name": "tpu", "resource": "tpu",
                                    "chips": 8, "chips_per_node": 2}]})
    cluster = orch.cluster_get("chaos")

    def trial(a, ctx):
        for _ in range(10):
            time.sleep(0.005)
            ctx.report(1, a["x"])
        return a["x"]

    monkey = ChaosMonkey(cluster, "tpu", period_s=0.05, heal_s=0.02).start()
    try:
        cfg = ExperimentConfig(name="n", budget=10, parallel=3,
                               optimizer="random", space=_space(),
                               resources=Resources(pool="tpu", chips=2),
                               max_retries=3)
        exp = orch.run(cfg, trial_fn=trial, cluster="chaos")
    finally:
        monkey.stop()
    st = orch.status(exp)
    assert monkey.kills >= 1
    assert st["observations"] == 10    # work survived node failures


def test_fault_injection_paths():
    orch = _orch()

    def trial(a, ctx):
        return a["x"]

    wrapped = wrap_trial(trial, FaultPolicy(p_crash=0.3, p_nan=0.2, seed=3))
    cfg = ExperimentConfig(name="f", budget=20, parallel=4,
                           optimizer="random", space=_space(), max_retries=0)
    exp = orch.run(cfg, trial_fn=wrapped)
    obs = orch.store.load_observations(exp)
    crashed = [o for o in obs if o.failed]
    nans = [o for o in obs if not o.failed and o.value is not None
            and np.isnan(o.value)]
    assert crashed, "some crashes expected"
    assert len(obs) == 20
