"""The paper's §4 alpha-test CNN: learns above chance, HPO trial works."""
import numpy as np

from repro.models.cnn import N_CLASSES, synthetic_signs, train_cnn


def test_dataset_deterministic_and_labeled():
    a = synthetic_signs(7, 32)
    b = synthetic_signs(7, 32)
    np.testing.assert_array_equal(a["image"], b["image"])
    assert a["label"].min() >= 0 and a["label"].max() < N_CLASSES


def test_cnn_learns_above_chance():
    reports = []
    acc = train_cnn({"lr": 3e-3, "momentum": 0.9, "fc_width": 64},
                    steps=50, batch=64,
                    report=lambda s, v: reports.append(v))
    assert acc > 3.0 / N_CLASSES          # >> 1/43 chance
    assert reports and reports[-1] >= reports[0] - 0.05


def test_bad_lr_does_worse():
    good = train_cnn({"lr": 3e-3, "momentum": 0.9}, steps=40)
    bad = train_cnn({"lr": 0.29, "momentum": 0.99}, steps=40)
    assert good > bad or bad < 0.2
