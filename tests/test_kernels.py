"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the image
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_quant import int8_quantize
from repro.kernels.rglru_scan import rglru_scan


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 64, 4, 4, 16),      # MHA
    (2, 160, 8, 2, 32),     # GQA, ragged S vs block
    (1, 300, 6, 1, 64),     # MQA, non-multiple S
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(B, S, H, K, D, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), dtype)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(1)
    B, S, H, K, D = 1, 200, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    out = flash_attention(q, k, v, window=window, bq=64, bk=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_softcap():
    rng = np.random.default_rng(2)
    B, S, H, K, D = 1, 128, 2, 2, 16
    q = jnp.asarray(rng.normal(0, 2, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 2, (B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, D)), jnp.float32)
    out = flash_attention(q, k, v, softcap=20.0, bq=64, bk=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("B,S,R", [(1, 64, 32), (2, 100, 96), (1, 257, 520)])
def test_rglru_scan_matches(B, S, R):
    rng = np.random.default_rng(0)
    la = jnp.asarray(-np.abs(rng.normal(0, 0.5, (B, S, R))), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (B, S, R)), jnp.float32)
    out = rglru_scan(la, b, bt=32, bf=64, interpret=True)
    want = ref.rglru_scan_ref(la, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 2000), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=12, deadline=None)
def test_int8_quant_roundtrip_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 2, (n,)), jnp.float32)
    q, s = int8_quantize(x, interpret=True)
    qr, sr = ref.int8_quant_ref(x)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # dequantization error bounded by half a quantization step per block
    deq = (np.asarray(q, np.float32)
           * np.asarray(s)[:, None]).reshape(-1)[:n]
    err = np.abs(deq - np.asarray(x))
    bound = np.repeat(np.asarray(s), 256)[:n] * 0.5 + 1e-7
    assert np.all(err <= bound)


def test_ops_fallback_paths_run():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 32, 2, 2, )[:3] + (16,)), jnp.float32)
    q = q.reshape(1, 32, 2, 16)
    k = v = jnp.asarray(rng.normal(0, 1, (1, 32, 2, 16)), jnp.float32)
    a = ops.flash_attention(q, k, v)                 # jnp fallback on CPU
    b = ops.flash_attention(q, k, v, force_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
