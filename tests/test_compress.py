"""Error-feedback int8 compressed psum under shard_map."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.compress import (compressed_psum, dequantize,
                                        quantize)


def test_quant_dequant_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 5, (1000,)), jnp.float32)
    q, s = quantize(x)
    y = dequantize(q, s, x.shape)
    err = np.abs(np.asarray(y - x))
    assert err.max() <= float(jnp.max(s)) * 0.5 + 1e-6


def test_compressed_psum_approximates_mean():
    n_dev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("d",))
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1, (n_dev, 64)), jnp.float32)
    e = jnp.zeros((n_dev, 64), jnp.float32)

    f = shard_map(lambda gg, ee: compressed_psum(gg[0], ee[0], "d"),
                  mesh=mesh, in_specs=(P("d"), P("d")),
                  out_specs=(P(), P("d")))
    red, new_e = f(g, e)
    want = np.mean(np.asarray(g), axis=0)
    np.testing.assert_allclose(np.asarray(red), want, atol=0.05)


def test_error_feedback_converges():
    """Accumulated compressed sums converge to the true sum (residual
    feedback means no systematic bias)."""
    rng = np.random.default_rng(2)
    true_acc = np.zeros(256)
    ef_acc = np.zeros(256)
    err = jnp.zeros(256, jnp.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
        corrected = g + err
        q, s = quantize(corrected)
        sent = dequantize(q, s, g.shape)
        err = corrected - sent
        true_acc += np.asarray(g)
        ef_acc += np.asarray(sent)
    # total drift bounded by the residual, not growing with t
    assert np.abs(true_acc - ef_acc).max() <= float(np.abs(err).max()) + 1e-5
