"""Suggestion-service API (v1): protocol round-trips, pending-suggestion
semantics, both backends end to end, resume replay, cluster-scoped stop."""
import json
import tempfile
import threading
import time

import pytest

from repro.api import (ApiError, CreateExperiment, HTTPClient, LocalClient,
                       ObserveRequest, StatusResponse, SuggestBatch,
                       Suggestion, serve_api)
from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)
from repro.core.suggest import make_optimizer


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg(name="api", budget=6, parallel=3, **kw):
    kw.setdefault("optimizer", "random")
    return ExperimentConfig(name=name, budget=budget, parallel=parallel,
                            space=_space(), **kw)


def _create(client, cfg, exp_id=None):
    return client.create_experiment(
        CreateExperiment(config=cfg.to_json(), exp_id=exp_id))


# ----------------------------------------------------------------- protocol
def test_protocol_messages_roundtrip_json():
    msgs = [
        CreateExperiment(config={"name": "m", "space": []}, exp_id="e1"),
        Suggestion("s00001", {"x": 0.5}),
        SuggestBatch([Suggestion("s00001", {"x": 0.5})], remaining=3),
        ObserveRequest("e1", "s00001", {"x": 0.5}, value=1.0,
                       trial_id="t0001", metadata={"runtime_s": 0.1}),
        StatusResponse("e1", state="running", name="m", budget=6,
                       observations=2, failures=1, pending=3,
                       best={"assignment": {"x": 0.5}, "value": 1.0}),
    ]
    for m in msgs:
        wire = json.loads(json.dumps(m.to_json()))
        assert type(m).from_json(wire) == m


def test_api_error_codes_map_to_http_status():
    assert ApiError("unknown_experiment", "x").http_status == 404
    assert ApiError("bad_request", "x").http_status == 400
    assert ApiError("internal", "x").http_status == 500


# -------------------------------------------------------------- LocalClient
def test_local_pending_tracking_caps_budget():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=5)).exp_id
    b1 = client.suggest(exp, 3)
    b2 = client.suggest(exp, 3)          # only 2 left: 5 - 0 - 3 pending
    assert len(b1) == 3 and len(b2) == 2 and b2.remaining == 0
    assert len(client.suggest(exp, 1)) == 0
    ids = [s.suggestion_id for s in b1.suggestions + b2.suggestions]
    assert len(set(ids)) == 5, "pending suggestions must be unique"
    # observing frees no budget (observed replaces pending) …
    s = b1.suggestions[0]
    client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment, 1.0))
    assert len(client.suggest(exp, 1)) == 0
    # … but releasing an unevaluated one does
    assert client.release(exp, b1.suggestions[1].suggestion_id)
    assert len(client.suggest(exp, 2)) == 1


def test_release_and_stop_retire_constant_liar_lies():
    """A released (or stopped) GP suggestion must drop its pending lie —
    otherwise every refit re-folds a point that will never be observed.
    Pins ``prefetch=0``: this asserts exact synchronous lie counts, which
    the prefetch pump's speculative asks would (correctly) perturb — the
    pipelined equivalents live in tests/test_pipeline.py."""
    client = LocalClient(tempfile.mkdtemp())
    cfg = _cfg(budget=30, optimizer="gp", prefetch=0,
               optimizer_options={"n_init": 2, "fit_steps": 30})
    exp = _create(client, cfg).exp_id
    for i in range(4):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    state = client._exps[exp]
    s = client.suggest(exp, 1).suggestions[0]
    assert state.optimizer._pending, "asked suggestion should hold a lie"
    client.release(exp, s.suggestion_id)
    assert not state.optimizer._pending, "release must retire the lie"
    client.suggest(exp, 2)
    assert len(state.optimizer._pending) == 2
    client.stop(exp)
    assert not state.optimizer._pending, "stop must retire all lies"


def test_best_readout_strips_internal_keys():
    client = LocalClient(tempfile.mkdtemp())
    cfg = _cfg(budget=20, optimizer="gp",
               optimizer_options={"n_init": 2, "fit_steps": 30})
    exp = _create(client, cfg).exp_id
    for i in range(5):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    for best in (client.status(exp).best, client.best_response(exp).best):
        assert best is not None
        assert not any(k.startswith("__") for k in best["assignment"]), \
            "internal echo keys must not leak into user-facing best"


def test_local_concurrent_suggest_never_duplicates():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=64, parallel=8)).exp_id
    out, lock = [], threading.Lock()

    def worker():
        got = []
        for _ in range(4):
            got.extend(client.suggest(exp, 2).suggestions)
        with lock:
            out.extend(got)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ids = [s.suggestion_id for s in out]
    assert len(ids) == 64 and len(set(ids)) == 64


def test_local_observe_duplicate_and_untracked():
    client = LocalClient(tempfile.mkdtemp())
    exp = _create(client, _cfg(budget=4)).exp_id
    s = client.suggest(exp, 1).suggestions[0]
    r1 = client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                       0.5, trial_id="t0001"))
    assert r1.accepted and not r1.duplicate and r1.observations == 1
    # a speculative twin reporting the same suggestion is a duplicate
    r2 = client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                       0.9, trial_id="t0001-spec1"))
    assert not r2.accepted and r2.duplicate and r2.observations == 1
    # untracked ids (service restarted) are tolerated, once
    r3 = client.observe(ObserveRequest(exp, "s-foreign", {"x": 0.1}, 0.2))
    assert r3.accepted and r3.observations == 2


def test_unknown_experiment_raises_api_error():
    client = LocalClient(tempfile.mkdtemp())
    with pytest.raises(ApiError) as ei:
        client.suggest("nope", 1)
    assert ei.value.code == "unknown_experiment"


# ------------------------------------------------- end-to-end, both backends
def test_scheduler_e2e_through_local_client():
    orch = Orchestrator(tempfile.mkdtemp())
    exp = orch.run(_cfg(budget=8, parallel=4),
                   trial_fn=lambda a, ctx: -(a["x"] - 0.4) ** 2)
    st = orch.status(exp)
    assert st["state"] == "complete"
    assert st["observations"] == 8
    assert st["pending"] == 0, "no pending suggestions may leak"
    assert len(orch.store.load_observations(exp)) == 8


def test_worker_loop_e2e_through_http_backend():
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        client = HTTPClient(server.url)
        assert client.healthz()["ok"]
        exp = _create(client, _cfg(name="http", budget=10)).exp_id
        seen = set()
        # bare worker loop, exactly the paper's suggest/observe protocol
        while True:
            batch = client.suggest(exp, 2)
            if not batch.suggestions:
                if client.status(exp).observations >= 10:
                    break
                continue
            for s in batch.suggestions:
                assert s.suggestion_id not in seen, "duplicate suggestion"
                seen.add(s.suggestion_id)
                client.observe(ObserveRequest(
                    exp, s.suggestion_id, s.assignment,
                    value=-(s.assignment["x"] - 0.25) ** 2))
        st = client.status(exp)
        assert st.observations == 10 and st.pending == 0
        assert st.state == "complete"
        assert client.best(exp) is not None
        # observations are the service store's, in perpetuity
        assert len(server.backend.store.load_observations(exp)) == 10
    finally:
        server.shutdown()


def test_scheduler_drives_remote_service():
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        orch = Orchestrator(tempfile.mkdtemp())   # worker-local store
        exp = orch.run(_cfg(name="remote", budget=6, parallel=2),
                       trial_fn=lambda a, ctx: a["x"], service=server.url)
        st = orch.status(exp)
        assert st["observations"] == 6 and st["state"] == "complete"
        # observation log lives on the service; logs live with the worker
        assert len(server.backend.store.load_observations(exp)) == 6
        assert orch.store.load_observations(exp) == []
        assert list(orch.store.iter_logs(exp))
    finally:
        server.shutdown()


def test_two_schedulers_share_one_http_experiment():
    """The paper's distributed scenario: several worker processes drive
    ONE experiment through the service; the budget is honored globally."""
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        client = HTTPClient(server.url)
        cfg = _cfg(name="shared", budget=12, parallel=2)
        exp = _create(client, cfg).exp_id

        def run_worker():
            orch = Orchestrator(tempfile.mkdtemp())
            orch.run(_cfg(name="shared", budget=12, parallel=2),
                     trial_fn=lambda a, ctx: a["x"], exp_id=exp,
                     service=server.url)

        workers = [threading.Thread(target=run_worker) for _ in range(2)]
        for t in workers:
            t.start()
        for t in workers:
            t.join(60)
        st = client.status(exp)
        assert st.observations == 12 and st.pending == 0
        assert len(server.backend.store.load_observations(exp)) == 12
    finally:
        server.shutdown()


def test_http_error_codes_over_the_wire():
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        client = HTTPClient(server.url)
        with pytest.raises(ApiError) as ei:
            client.suggest("missing", 1)
        assert ei.value.code == "unknown_experiment"
        with pytest.raises(ApiError) as ei:
            client._call("POST", "/v1/experiments/x/bogus", {})
        assert ei.value.code == "bad_request"
        with pytest.raises(ApiError) as ei:
            client._call("POST", "/v1/experiments", {})   # no config
        assert ei.value.code == "bad_request"
    finally:
        server.shutdown()


# ------------------------------------------------------------------- resume
def test_resume_replays_observations_exactly_once():
    root = tempfile.mkdtemp()
    orch = Orchestrator(root)
    calls = []
    cfg = _cfg(name="resume", budget=4, parallel=2, optimizer="gp")
    exp = orch.run(cfg, trial_fn=lambda a, ctx: calls.append(1) or a["x"])
    assert len(calls) == 4

    # fresh process: the service replays the log into a fresh optimizer
    client = LocalClient(root)
    resp = _create(client, _cfg(name="resume", budget=8, parallel=2,
                                optimizer="gp"), exp_id=exp)
    assert resp.resumed and resp.observations == 4
    opt = client._exps[exp].optimizer
    assert len(opt.history) == 4
    # creating again must NOT double-count (restore is idempotent)
    _create(client, _cfg(name="resume", budget=8, parallel=2,
                         optimizer="gp"), exp_id=exp)
    assert len(opt.history) == 4

    # resumed run continues from the correct budget position
    calls2 = []
    orch2 = Orchestrator(root, client=client)
    exp2 = orch2.run(_cfg(name="resume", budget=8, parallel=2,
                          optimizer="gp"),
                     trial_fn=lambda a, ctx: calls2.append(1) or a["x"],
                     exp_id=exp)
    assert exp2 == exp
    assert len(calls2) == 4, "resume must only run the remaining budget"
    assert len(orch2.store.load_observations(exp)) == 8
    assert len(opt.history) == 8


def test_optimizer_restore_is_idempotent():
    space = _space()
    opt = make_optimizer("random", space, seed=0)
    log = [{"assignment": {"x": 0.1 * i}, "value": float(i)}
           for i in range(5)]
    opt.restore({"history": log})
    opt.restore({"history": log})
    assert len(opt.history) == 5
    # longer log: only the tail is replayed
    opt.restore({"history": log + [{"assignment": {"x": 0.9}, "value": 9.0}]})
    assert len(opt.history) == 6


# ------------------------------------------------- cluster-scoped shutdown
def test_cluster_destroy_only_stops_its_own_experiments():
    orch = Orchestrator(tempfile.mkdtemp())
    for name in ("a", "b"):
        orch.cluster_create({"cluster_name": name,
                             "pools": [{"name": "tpu", "resource": "tpu",
                                        "chips": 8}]})
    gate = threading.Event()

    def slow_trial(a, ctx):
        gate.wait(10)
        return a["x"]

    res = Resources(pool="tpu", chips=2)
    exp_a = orch.run(_cfg(name="on-a", budget=4, parallel=2, resources=res),
                     trial_fn=slow_trial, cluster="a", background=True)
    exp_b = orch.run(_cfg(name="on-b", budget=4, parallel=2, resources=res),
                     trial_fn=slow_trial, cluster="b", background=True)
    time.sleep(0.3)
    orch.cluster_destroy("a")
    assert orch._schedulers[exp_a].finished
    assert not orch._schedulers[exp_b].finished, \
        "destroying cluster 'a' must not stop experiments on cluster 'b'"
    gate.set()
    orch.wait(exp_a, 10)
    orch.wait(exp_b, 10)
    assert orch.status(exp_b)["observations"] == 4
