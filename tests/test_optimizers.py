"""Every optimizer: in-bounds suggestions, convergence, failure handling."""
import numpy as np
import pytest

from repro.core.space import Param, Space
from repro.core.suggest import Observation, make_optimizer

NAMES = ["random", "grid", "sobol", "evolution", "pso", "gp"]


def _space():
    return Space([Param("x", "double", 0, 1),
                  Param("y", "double", 1e-4, 1e0, log=True)])


def _f(a):
    return -((a["x"] - 0.62) ** 2 + (np.log10(a["y"]) + 2.0) ** 2)


@pytest.mark.parametrize("name", NAMES)
def test_in_bounds_and_improves(name):
    space = _space()
    opt = make_optimizer(name, space, seed=1)
    first = None
    for _ in range(12):
        asks = opt.ask(4)
        obs = []
        for a in asks:
            clean = {k: v for k, v in a.items() if not k.startswith("__")}
            assert space.validate(clean)
            obs.append(Observation(
                clean, _f(clean),
                metadata={k: v for k, v in a.items() if k.startswith("__")}))
        if first is None:
            first = max(o.value for o in obs)
        opt.tell(obs)
    best = opt.best().value
    assert best >= first          # never worse than the first batch
    assert best > -1.0            # actually found a decent region


@pytest.mark.parametrize("name", NAMES)
def test_failed_observations_dont_crash(name):
    space = _space()
    opt = make_optimizer(name, space, seed=0)
    for _ in range(4):
        asks = opt.ask(2)
        opt.tell([Observation(
            {k: v for k, v in a.items() if not k.startswith("__")},
            None, failed=True) for a in asks])
    # optimizer still asks after only failures
    assert len(opt.ask(2)) == 2
    assert opt.best() is None


def test_parallel_gp_asks_are_distinct():
    """Constant-liar: simultaneous suggestions must not collapse."""
    space = _space()
    opt = make_optimizer("gp", space, seed=0, n_init=4)
    for _ in range(3):
        asks = opt.ask(4)
        opt.tell([Observation(a, _f(a)) for a in asks])
    batch = opt.ask(6)
    pts = np.array([space.to_unit(a) for a in batch])
    d = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
    np.fill_diagonal(d, 1.0)
    assert d.min() > 1e-4


def test_state_restore_resumes():
    space = _space()
    opt = make_optimizer("gp", space, seed=0)
    for _ in range(3):
        asks = opt.ask(3)
        opt.tell([Observation(a, _f(a)) for a in asks])
    st = opt.state()
    opt2 = make_optimizer("gp", space, seed=0)
    opt2.restore(st)
    assert len(opt2.history) == len(opt.history)
    assert opt2.best().value == opt.best().value
