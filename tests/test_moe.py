"""MoE routing invariants (hypothesis) + dispatch/combine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the image
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import moe as M
from repro.models.common import ModelConfig


def _cfg(E=4, k=2, cf=1.25):
    return get_config("granite-moe-3b-a800m").reduced(
        n_experts=E, top_k=k, capacity_factor=cf, d_ff_expert=32,
        d_model=48, n_heads=4, n_kv_heads=2)


def test_router_weights_normalized():
    cfg = _cfg()
    p = M.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 16, 48)),
                    jnp.float32)
    w, idx, aux = M._router(p, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert float(aux) >= 1.0 - 1e-5      # E * sum f_e P_e >= 1 at balance
    assert int(jnp.max(idx)) < cfg.n_experts


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_no_drop_high_capacity_equals_dense(seed, E, k):
    """With capacity >= S the dispatch path must equal the dense masked
    combine (the decode path) exactly."""
    cfg = _cfg(E=E, k=k, cf=float(E * 4))
    rng = np.random.default_rng(seed)
    p = M.init_moe(jax.random.key(seed % 100), cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 12, 48)), jnp.float32)
    y_dispatch, _ = M.moe_forward(p, x, cfg)
    w, idx, _ = M._router(p, x, cfg)
    y_dense = M._moe_decode(p, x, w, idx, cfg)
    np.testing.assert_allclose(np.asarray(y_dispatch), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_tokens_when_overloaded():
    cfg = _cfg(E=4, k=2, cf=0.3)
    p = M.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1, 64, 48)),
                    jnp.float32)
    y_low, _ = M.moe_forward(p, x, cfg)
    cfg_hi = _cfg(E=4, k=2, cf=100.0)
    y_hi, _ = M.moe_forward(p, x, cfg_hi)
    assert not np.allclose(np.asarray(y_low), np.asarray(y_hi))


def test_capacity_formula_bounds():
    cfg = _cfg(E=8, k=2, cf=1.0)
    c = M.capacity(cfg, 64)
    assert 8 <= c <= 64
    assert M.capacity(cfg, 4) >= 4 or M.capacity(cfg, 4) == 8  # floor


def test_grad_flows_through_dispatch():
    cfg = _cfg()
    p = M.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (1, 8, 48)),
                    jnp.float32)

    def loss(pp):
        y, aux = M.moe_forward(pp, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
