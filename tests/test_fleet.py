"""Fleet subsystem: consistent-hash routing, heartbeat liveness,
admission control, and the dead-worker / dead-shard fault paths
(requeue-and-serve-exactly-once, config-less failover adoption).

The in-process tests drive the manager's event loop deterministically
(``tick()`` + fake-clock registry); the kill −9 tests use real
subprocesses so the connection-reset path — not a polite shutdown — is
what the router and manager see.
"""
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from repro.api.local import LocalClient
from repro.api.protocol import (ApiError, CreateExperiment, E_FLEET_BUSY,
                                ObserveRequest, ReportRequest)
from repro.core import ExperimentConfig, Orchestrator, Param, Space
from repro.fleet import (FleetClient, FleetManager, HashRing, S_ALIVE,
                         S_DEAD, S_REGISTERED, S_SUSPECT, WorkerRegistry,
                         serve_fleet)

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _space():
    return Space([Param("x", "double", 0, 1)])


def _cfg(**kw):
    kw.setdefault("optimizer", "random")
    kw.setdefault("space", _space())
    return ExperimentConfig(**kw)


def _cfg_json(name, budget=6, **kw):
    return dict(_cfg(name=name, budget=budget, **kw).to_json())


def _inproc_fleet(n=3, root=None, **kw):
    """Manager over n in-process LocalClient shards sharing one store."""
    root = root or tempfile.mkdtemp()
    manager = FleetManager(**kw)
    for i in range(n):
        manager.add_shard(LocalClient(root), shard_id=f"shard-{i}")
    return manager, root


# ------------------------------------------------------------------ hashring
def test_hashring_owner_is_stable_and_minimally_disrupted():
    keys = [f"exp-{i}" for i in range(200)]
    r1 = HashRing(["a", "b", "c"])
    r2 = HashRing(["a", "b", "c"])
    # blake2b: two independent rings (≈ two processes) agree on every key
    assert [r1.owner(k) for k in keys] == [r2.owner(k) for k in keys]
    before = {k: r1.owner(k) for k in keys}
    r1.remove("b")
    after = {k: r1.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # consistent hashing: ONLY b's keys re-home
    assert all(before[k] == "b" for k in moved)
    assert all(after[k] in ("a", "c") for k in keys)
    # balance: every node owns a non-trivial share
    spread = HashRing(["a", "b", "c", "d"]).spread(keys)
    assert all(v > len(keys) / 16 for v in spread.values()), spread


def test_hashring_add_remove_roundtrip():
    ring = HashRing(["a", "b"])
    assert "a" in ring and len(ring) == 2
    ring.add("a")                       # idempotent
    assert len(ring) == 2
    ring.remove("missing")              # no-op
    ring.remove("a")
    assert "a" not in ring
    assert all(ring.owner(f"k{i}") == "b" for i in range(20))
    ring.remove("b")
    assert ring.owner("k") is None


# ------------------------------------------------------------------ registry
def test_registry_state_machine_with_fake_clock():
    reg = WorkerRegistry(period=1.0)    # suspect at 1s, dead at 2s silent
    reg.register("w1", now=0.0)
    assert reg.state("w1") == S_REGISTERED
    assert reg.beat("w1", now=0.5) == S_ALIVE
    assert reg.sweep(now=1.0) == []     # 0.5s silent: still alive
    assert reg.state("w1") == S_ALIVE
    reg.sweep(now=1.8)                  # 1.3s silent: suspect
    assert reg.state("w1") == S_SUSPECT
    assert reg.beat("w1", now=2.0) == S_ALIVE   # beat recovers suspect
    dead = reg.sweep(now=4.5)           # 2.5s silent: dead
    assert [r.worker_id for r in dead] == ["w1"]
    assert reg.state("w1") == S_DEAD
    assert reg.sweep(now=5.0) == []     # dead reported exactly once
    # a dead worker re-registering is a NEW incarnation with clean holdings
    reg.get("w1").holdings = {"e": ["s1"]}
    rec = reg.register("w1", now=6.0)
    assert rec.state == S_REGISTERED and rec.holdings == {}


def test_registry_beat_autoregisters_and_carries_holdings():
    reg = WorkerRegistry(period=1.0)
    # manager restart: an unknown worker's beat must not be dropped
    assert reg.beat("w9", holdings={"e1": ["sA", "sB"]}, now=0.0) == S_ALIVE
    assert reg.get("w9").holdings == {"e1": ["sA", "sB"]}
    dead = reg.sweep(now=10.0)
    assert [r.worker_id for r in dead] == ["w9"]
    assert dead[0].holdings == {"e1": ["sA", "sB"]}


# ------------------------------------------------------------------- routing
def test_fleet_routes_and_spreads_experiments_across_shards():
    manager, _ = _inproc_fleet(3)
    client = FleetClient(manager, heartbeat=False)
    owners = set()
    for i in range(8):
        eid = client.create_experiment(
            CreateExperiment(config=_cfg_json(f"route-{i}", budget=2),
                             exp_id=f"exp-route-{i:02d}")).exp_id
        owners.add(manager.owner_of(eid).shard_id)
        batch = client.suggest(eid, 1)
        assert len(batch) == 1
        s = batch.suggestions[0]
        r = client.observe(ObserveRequest(eid, s.suggestion_id,
                                          s.assignment, value=0.5))
        assert r.accepted
        assert client.status(eid).observations == 1
    # 8 experiments over 3 shards: consistent hashing spreads them
    assert len(owners) > 1
    # the experiment lives ONLY on its owner shard
    eid = "exp-route-00"
    owner = manager.owner_of(eid).shard_id
    for sid, handle in manager._shards.items():
        assert (eid in handle.client._exps) == (sid == owner)
    client.close()


def test_fleet_map_versioning_on_membership_change():
    manager, root = _inproc_fleet(2)
    v0 = manager.shard_map().version
    manager.add_shard(LocalClient(root), shard_id="shard-late")
    m = manager.shard_map()
    assert m.version == v0 + 1 and "shard-late" in m.shards
    manager.remove_shard("shard-late")
    assert manager.shard_map().version == v0 + 2
    client = FleetClient(manager, heartbeat=False)
    assert client.map_version == v0 + 2
    client.close()


# ----------------------------------------------------------------- admission
def test_admission_redirects_create_away_from_saturated_owner():
    manager, _ = _inproc_fleet(3, admit_backlog=4)
    exp_id = "exp-sat-1"
    owner = manager.owner_of(exp_id)
    owner.load = {"backlog": 9, "duty": 0.0, "live": 5}   # saturated
    client = FleetClient(manager, heartbeat=False)
    resp = client.create_experiment(
        CreateExperiment(config=_cfg_json("sat", budget=4), exp_id=exp_id))
    m = manager.shard_map()
    assert m.overrides.get(exp_id) not in (None, owner.shard_id)
    assert manager.stats["redirects"] == 1
    # the override routes ALL later traffic: suggest works via the client
    assert len(client.suggest(resp.exp_id, 1)) == 1
    # redirect target actually hosts it
    target = manager._shards[m.overrides[exp_id]]
    assert exp_id in target.client._exps
    assert exp_id not in owner.client._exps
    client.close()


def test_admission_busy_when_every_shard_is_saturated():
    manager, _ = _inproc_fleet(2, admit_duty=0.5)
    for handle in manager._shards.values():
        handle.load = {"backlog": 0, "duty": 0.9, "live": 4}
    with pytest.raises(ApiError) as ei:
        manager.create_experiment(
            CreateExperiment(config=_cfg_json("busy"), exp_id="exp-busy"))
    assert ei.value.code == E_FLEET_BUSY
    assert manager.stats["busy_rejections"] == 1
    # nothing was created anywhere
    assert all("exp-busy" not in h.client._exps
               for h in manager._shards.values())


def test_shard_load_probe_reports_executor_signal():
    manager, _ = _inproc_fleet(1)
    handle = next(iter(manager._shards.values()))
    assert handle.probe()
    assert {"experiments", "live", "pending", "backlog", "duty"} \
        <= set(handle.load)


# --------------------------------------------------------------- fault paths
def test_dead_worker_holdings_requeued_and_served_exactly_once():
    manager, _ = _inproc_fleet(2)
    client = FleetClient(manager, heartbeat=False)
    eid = client.create_experiment(
        CreateExperiment(config=_cfg_json("dw", budget=6),
                         exp_id="exp-dw")).exp_id
    batch = client.suggest(eid, 3)
    taken = {s.suggestion_id for s in batch.suggestions}
    assert len(taken) == 3
    # worker heartbeats its holdings, then goes silent
    reg = manager.registry
    reg.beat("w-dead", holdings=client.holdings(), now=0.0)
    for rec in reg.sweep(now=10.0):
        manager._on_dead_worker(rec)
    assert manager.stats["requeued"] == 3
    # requeued suggestions keep their ids and are served before fresh ones
    survivor = FleetClient(manager, heartbeat=False)
    got = survivor.suggest(eid, 6)
    ids = [s.suggestion_id for s in got.suggestions]
    assert set(ids[:3]) == taken            # orphans first, same ids
    assert len(ids) == len(set(ids)) == 6   # budget headroom intact
    # ...exactly once: nothing left to serve
    assert len(survivor.suggest(eid, 6)) == 0
    for s in got.suggestions:
        r = survivor.observe(ObserveRequest(eid, s.suggestion_id,
                                            s.assignment, value=0.5))
        assert r.accepted and not r.duplicate
    st = survivor.status(eid)
    assert st.observations == 6 and st.pending == 0
    # no leaked lies: the shard's optimizer has no outstanding pendings
    owner = manager.owner_of(eid)
    state = owner.client._exps[eid]
    assert state.pending == {}
    assert not getattr(state.optimizer, "_pending", {})
    client.close()
    survivor.close()


def test_requeue_tolerates_observed_and_unknown_suggestions():
    manager, _ = _inproc_fleet(1)
    client = FleetClient(manager, heartbeat=False)
    eid = client.create_experiment(
        CreateExperiment(config=_cfg_json("rq", budget=3),
                         exp_id="exp-rq")).exp_id
    s = client.suggest(eid, 1).suggestions[0]
    assert client.requeue(eid, s.suggestion_id) is True
    assert client.requeue(eid, s.suggestion_id) is True   # dedupe, no double
    got = client.suggest(eid, 3)
    assert [x.suggestion_id for x in got.suggestions][0] == s.suggestion_id
    assert len({x.suggestion_id for x in got.suggestions}) == len(got)
    r = client.observe(ObserveRequest(eid, s.suggestion_id, s.assignment,
                                      value=1.0))
    assert r.accepted
    # already observed -> not requeueable; unknown -> not requeueable
    assert client.requeue(eid, s.suggestion_id) is False
    assert client.requeue(eid, "s-never-existed") is False
    client.close()


def test_scheduler_crash_mid_report_through_router_leaves_no_orphans():
    """InjectedCrash after a progress report, with suggestions routed
    through the fleet: no orphaned pending, no stale constant-liar lie."""
    from repro.core.faults import InjectedCrash
    manager, root = _inproc_fleet(2)
    fleet_client = FleetClient(manager, heartbeat=False)
    orch = Orchestrator(root, client=fleet_client)

    def trial(a, ctx):
        ctx.report(1, a["x"])
        raise InjectedCrash("mid-report crash")

    cfg = _cfg(name="fleet-midreport", budget=4, parallel=2, max_retries=0)
    exp = orch.run(cfg, trial_fn=trial)
    for handle in manager._shards.values():
        state = handle.client._exps.get(exp)
        if state is None:
            continue
        assert state.pending == {}, "crashed trials must not hold pending"
        assert not getattr(state.optimizer, "_pending", {})
    obs = orch.store.load_observations(exp)
    assert len(obs) == 4 and all(o.failed for o in obs)
    assert fleet_client.holdings() == {}, "observed holdings must clear"
    fleet_client.close()


def test_fail_nodes_during_pause_resume_through_router():
    """cluster.fail_nodes (via ChaosMonkey) revokes leases while trials
    pause/resume under an early-stopping policy, with every suggestion
    routed through the fleet: the run still completes exactly on budget,
    all leases return to the pool, and no shard is left with orphaned
    pending suggestions or stale constant-liar lies."""
    from repro.core import Resources
    from repro.core.faults import ChaosMonkey
    manager, root = _inproc_fleet(2)
    fleet_client = FleetClient(manager, heartbeat=False)
    orch = Orchestrator(root, client=fleet_client)
    orch.cluster_create({"cluster_name": "f",
                         "pools": [{"name": "tpu", "resource": "tpu",
                                    "chips": 8, "chips_per_node": 2}]})
    cluster = orch.cluster_get("f")

    def trial(a, ctx):
        start = ctx.resume_step or 0
        for step in (1, 2, 4):
            if step <= start:
                continue
            time.sleep(0.005)
            ctx.report(step, a["x"])
        return a["x"]

    monkey = ChaosMonkey(cluster, "tpu", period_s=0.05, heal_s=0.02).start()
    try:
        cfg = _cfg(name="fleet-revoke", budget=6, parallel=3,
                   resources=Resources(pool="tpu", chips=2), max_retries=3,
                   early_stop={"min_steps": 1, "eta": 2, "mode": "pause"})
        exp = orch.run(cfg, trial_fn=trial, cluster="f")
    finally:
        monkey.stop()
    assert monkey.kills >= 1
    obs = orch.store.load_observations(exp)
    assert len(obs) == 6, "work must survive node failures"
    assert orch.cluster_status("f")["pools"]["tpu"]["free"] == 8
    for handle in manager._shards.values():
        state = handle.client._exps.get(exp)
        if state is not None:
            assert state.pending == {}
            assert not state.orphaned
            assert not getattr(state.optimizer, "_pending", {})
    assert fleet_client.holdings() == {}
    fleet_client.close()


def test_dead_shard_failover_adopts_from_shared_store():
    """Kill a shard's listener + sever its connections: the manager drops
    it from the ring, the ring successor adopts the experiment out of the
    shared store, and the router re-homes transparently."""
    root = tempfile.mkdtemp()
    srv = serve_fleet(root, shards=3, period=0.2).start()
    try:
        client = FleetClient(srv.url, heartbeat=True)
        eid = client.create_experiment(CreateExperiment(
            config=_cfg_json("failover", budget=8),
            exp_id="exp-failover")).exp_id
        pre = client.suggest(eid, 2)
        for s in pre.suggestions:
            assert client.observe(ObserveRequest(
                eid, s.suggestion_id, s.assignment, value=0.7)).accepted
        owner = srv.manager.owner_of(eid).shard_id
        victim = next(s for i, s in enumerate(srv.owned_shards)
                      if f"shard-{i}" == owner)
        victim._httpd.shutdown()
        victim._httpd.server_close()
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline \
                and srv.manager.stats["dead_shards"] < 1:
            time.sleep(0.05)
        assert srv.manager.stats["dead_shards"] == 1
        assert owner not in srv.manager.shard_map().shards
        client.beat()           # pick up the post-death map
        post = client.suggest(eid, 2)
        assert len(post) == 2
        pre_ids = {s.suggestion_id for s in pre.suggestions}
        assert not (pre_ids & {s.suggestion_id for s in post.suggestions}), \
            "suggestion ids must be unique across shard incarnations"
        for s in post.suggestions:
            r = client.observe(ObserveRequest(eid, s.suggestion_id,
                                              s.assignment, value=0.6))
            assert r.accepted and not r.duplicate
        st = client.status(eid)
        assert st.observations == 4 and st.pending == 0
        client.close()
    finally:
        srv.shutdown()


# ---------------------------------------------------------------- kill -9
_SHARD_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
from repro.api.http import serve_api
srv = serve_api({root!r}, port=0)
print(srv.url, flush=True)
srv.serve_forever()
"""

_WORKER_SCRIPT = """
import sys, time
sys.path.insert(0, {src!r})
from repro.fleet import FleetClient
client = FleetClient({fleet_url!r}, worker_id="victim", heartbeat=True)
held = []
for eid in {exp_ids!r}:
    held += [s.suggestion_id for s in client.suggest(eid, 1).suggestions]
client.beat()                     # holdings reach the manager
print("HELD " + " ".join(held), flush=True)
time.sleep(600)                   # wedge until killed
"""


def _spawn(script, **fmt):
    proc = subprocess.Popen([sys.executable, "-c", script.format(**fmt)],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    line = proc.stdout.readline().strip()
    assert line, proc.stderr.read()
    return proc, line


def test_kill9_scheduler_requeues_within_two_periods():
    """Acceptance: kill −9 a scheduler holding pending suggestions under
    k=8-experiment load — every held suggestion is requeued and served to
    a survivor within ~2 heartbeat periods, exactly once, with no
    duplicate observes and no leaked lies."""
    root = tempfile.mkdtemp()
    period = 0.5
    srv = serve_fleet(root, shards=2, period=period).start()
    worker = None
    try:
        boss = FleetClient(srv.url, heartbeat=False)
        exp_ids = []
        for i in range(8):
            exp_ids.append(boss.create_experiment(CreateExperiment(
                config=_cfg_json(f"k9-{i}", budget=3),
                exp_id=f"exp-k9-{i}")).exp_id)
        worker, line = _spawn(_WORKER_SCRIPT, src=SRC, fleet_url=srv.url,
                              exp_ids=exp_ids)
        held = set(line.split()[1:])
        assert len(held) == 8
        t_kill = time.monotonic()
        os.kill(worker.pid, signal.SIGKILL)
        deadline = t_kill + 30
        while time.monotonic() < deadline \
                and srv.manager.stats["requeued"] < 8:
            time.sleep(0.05)
        t_requeued = time.monotonic()
        assert srv.manager.stats["requeued"] == 8, srv.manager.stats
        # dead_after defaults to 2 periods; allow scheduling slack on top
        assert t_requeued - t_kill < 2 * period + 3.0
        # survivors get exactly the held suggestions, once each
        survivor = FleetClient(srv.url, heartbeat=False)
        served = []
        for eid in exp_ids:
            got = survivor.suggest(eid, 3)
            ids = [s.suggestion_id for s in got.suggestions]
            assert len(set(ids)) == len(ids)
            served += [(eid, s) for s in got.suggestions]
        assert held <= {s.suggestion_id for _, s in served}
        for eid, s in served:
            r = survivor.observe(ObserveRequest(eid, s.suggestion_id,
                                                s.assignment, value=0.5))
            assert r.accepted and not r.duplicate, (eid, s.suggestion_id)
        for eid in exp_ids:
            st = survivor.status(eid)
            assert st.observations == 3 and st.pending == 0, st.to_json()
        boss.close()
        survivor.close()
    finally:
        if worker is not None and worker.poll() is None:
            worker.kill()
        srv.shutdown()


@pytest.mark.slow
def test_kill9_shard_under_load_survivors_serve_all_experiments():
    """Acceptance: kill −9 one SHARD process under k=8-experiment load;
    survivors adopt its experiments from the shared store and every
    experiment completes exactly on budget — no duplicate observes."""
    root = tempfile.mkdtemp()
    period = 0.5
    shard_a, url_a = _spawn(_SHARD_SCRIPT, src=SRC, root=root)
    shard_b, url_b = _spawn(_SHARD_SCRIPT, src=SRC, root=root)
    srv = serve_fleet(shard_urls=[url_a, url_b], period=period).start()
    try:
        client = FleetClient(srv.url, heartbeat=True)
        exp_ids = []
        for i in range(8):
            exp_ids.append(client.create_experiment(CreateExperiment(
                config=_cfg_json(f"ks-{i}", budget=4),
                exp_id=f"exp-ks-{i}")).exp_id)
        first = {eid: client.suggest(eid, 2) for eid in exp_ids}
        for eid, batch in first.items():
            s = batch.suggestions[0]
            assert client.observe(ObserveRequest(
                eid, s.suggestion_id, s.assignment, value=0.4)).accepted
        os.kill(shard_a.pid, signal.SIGKILL)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline \
                and srv.manager.stats["dead_shards"] < 1:
            time.sleep(0.05)
        assert srv.manager.stats["dead_shards"] == 1
        client.beat()
        # the client still holds each experiment's second suggestion; a
        # real scheduler reports those results after failover.  On the
        # survivor this is the normal path; on an adopted experiment the
        # id is untracked (the pending set died with the shard) and the
        # service accepts it as real data.
        observed = set()
        for eid in exp_ids:
            s = first[eid].suggestions[1]
            r = client.observe(ObserveRequest(eid, s.suggestion_id,
                                              s.assignment, value=0.3))
            assert r.accepted and not r.duplicate, (eid, s.suggestion_id)
            observed.add((eid, s.suggestion_id))
        # drive every experiment to completion: the adopting shard
        # reclaimed the dead shard's pending budget via log replay, so
        # fresh suggests cover the remainder.  Ids never collide.
        for eid in exp_ids:
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                st = client.status(eid)
                if st.observations >= 4:
                    break
                got = client.suggest(eid, 4)
                if not got.suggestions:
                    time.sleep(0.1)
                    continue
                for s in got.suggestions:
                    r = client.observe(ObserveRequest(
                        eid, s.suggestion_id, s.assignment, value=0.5))
                    assert r.accepted and not r.duplicate
                    key = (eid, s.suggestion_id)
                    assert key not in observed, "duplicate observe"
                    observed.add(key)
            st = client.status(eid)
            assert st.observations == 4 and st.pending == 0, \
                (eid, st.to_json())
        client.close()
    finally:
        for p in (shard_a, shard_b):
            if p.poll() is None:
                p.kill()
        srv.shutdown()


# ------------------------------------------------------- graceful shutdown
@pytest.mark.parametrize("verb,extra", [
    ("serve-api", []),
    ("serve-fleet", ["--shards", "1"]),
])
def test_sigterm_shuts_down_serve_processes_cleanly(verb, extra):
    root = tempfile.mkdtemp()
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.cli", "--store", root,
         verb, "--port", "0"] + extra,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=dict(os.environ, PYTHONPATH=SRC, PYTHONUNBUFFERED="1"))
    line = proc.stdout.readline()
    assert "listening on" in line, proc.stderr.read()
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=20)
    assert proc.returncode == 0, err
    assert "shut down cleanly" in err, err


# -------------------------------------------------- file-handle discipline
def test_terminal_trial_evicts_metric_handle():
    root = tempfile.mkdtemp()
    client = LocalClient(root)
    eid = client.create_experiment(CreateExperiment(
        config=_cfg_json("evict", budget=2))).exp_id
    s = client.suggest(eid, 1).suggestions[0]
    client.report(ReportRequest(eid, "t1", step=1, value=0.5,
                                suggestion_id=s.suggestion_id))
    # the metric stream is keyed by suggestion_id when one is reported
    p = client.store.metric_path(eid, s.suggestion_id)
    assert p in client.store._log_handles, "report keeps the handle warm"
    client.observe(ObserveRequest(eid, s.suggestion_id, s.assignment,
                                  value=0.5, trial_id="t1"))
    assert p not in client.store._log_handles, \
        "terminal observe must evict the trial's metric handle"


def test_open_handles_stay_bounded_at_fleet_scale():
    """Fleet-sized load: many trials across many experiments, every trial
    reaching a terminal state — open handles stay proportional to LIVE
    trials (here: 0), far under the LRU cap."""
    from repro.core.store import LOG_HANDLE_CACHE
    root = tempfile.mkdtemp()
    client = LocalClient(root)
    n_exp, per_exp = 6, 20      # 120 trials > LOG_HANDLE_CACHE (64)
    for e in range(n_exp):
        eid = client.create_experiment(CreateExperiment(
            config=_cfg_json(f"cap-{e}", budget=per_exp))).exp_id
        for t in range(per_exp):
            s = client.suggest(eid, 1).suggestions[0]
            tid = f"t{t:03d}"
            client.report(ReportRequest(eid, tid, step=1, value=0.1,
                                        suggestion_id=s.suggestion_id))
            client.observe(ObserveRequest(eid, s.suggestion_id,
                                          s.assignment, value=0.1,
                                          trial_id=tid))
        assert client.store.open_handles() <= LOG_HANDLE_CACHE
    assert client.store.open_handles() == 0, \
        "all trials terminal -> all metric handles evicted"


# ------------------------------------------------- sparse quality counter
def test_sparse_vs_exact_regret_counters_in_status():
    root = tempfile.mkdtemp()
    client = LocalClient(root)
    eid = client.create_experiment(CreateExperiment(
        config=_cfg_json("quality", budget=8))).exp_id
    state = client._exps[eid]
    # mint two sparse-served and two exact-served suggestions, observe
    # with known regrets against the running best
    with state.lock:
        sugg = [client._mint(state, {"x": 0.5}, sparse=(i % 2 == 0))
                for i in range(4)]
    values = [1.0, 0.9, 0.8, 1.0]   # regrets vs best-so-far: 0, .1, .2, 0
    for s, v in zip(sugg, values):
        client.observe(ObserveRequest(eid, s.suggestion_id, s.assignment,
                                      value=v))
    q = client.status(eid).pump["quality"]
    assert q["sparse_n"] == 2 and q["exact_n"] == 2
    assert q["sparse_mean_regret"] == pytest.approx(0.1)   # (0 + .2) / 2
    assert q["exact_mean_regret"] == pytest.approx(0.05)   # (.1 + 0) / 2


def test_quality_counters_empty_until_observations():
    root = tempfile.mkdtemp()
    client = LocalClient(root)
    eid = client.create_experiment(CreateExperiment(
        config=_cfg_json("quality0", budget=2))).exp_id
    q = client.status(eid).pump["quality"]
    assert q["sparse_n"] == 0 and q["sparse_mean_regret"] is None
    assert q["exact_n"] == 0 and q["exact_mean_regret"] is None


# --------------------------------------------------- transport robustness
def test_http_client_backoff_counters_on_refused_connect():
    """Bounded exponential backoff with full jitter: a refused connect
    retries up to ``retry_attempts`` times (any verb — the server
    provably never saw the request), then surfaces ``service
    unreachable``; every step lands in the per-client counters."""
    from repro.api.http import HTTPClient
    from repro.api.protocol import E_INTERNAL
    # a port nothing listens on -> instant ConnectionRefusedError
    c = HTTPClient("http://127.0.0.1:9", retry_attempts=3,
                   retry_base=0.001, retry_cap=0.002, retry_seed=0)
    with pytest.raises(ApiError) as ei:
        c.load()
    assert ei.value.code == E_INTERNAL
    assert "unreachable" in str(ei.value)
    assert c.stats["refused"] == 3, "one refused connect per attempt"
    assert c.stats["backoffs"] == 2, "every retry but the last slept"
    assert c.stats["gave_up"] == 1
    # non-idempotent verbs retry refused connects too (send-phase failure
    # = never reached the service), with the same bound
    with pytest.raises(ApiError):
        c.suggest("exp-x", 1)
    assert c.stats["refused"] == 6 and c.stats["gave_up"] == 2
    c.close()


def test_http_status_carries_transport_counters():
    from repro.api.http import HTTPClient, serve_api
    root = tempfile.mkdtemp()
    srv = serve_api(root).start()
    try:
        c = HTTPClient(srv.url, retry_seed=0)
        eid = c.create_experiment(CreateExperiment(
            config=_cfg_json("transport", budget=2))).exp_id
        st = c.status(eid)
        assert st.transport is not None
        assert {"retries", "backoffs", "backoff_ms", "refused",
                "gave_up"} <= set(st.transport)
        assert st.transport["gave_up"] == 0
        c.close()
    finally:
        srv.shutdown()


def test_probe_deadline_counts_wedged_shard_toward_death():
    """S2: a shard that accepts the probe but never answers must not
    stall the manager's tick — the shared per-round deadline expires,
    the probe counts as FAILED, and the shard progresses to dead
    instead of hiding behind the slow-not-dead re-beat guard."""
    class WedgedClient:
        def __init__(self):
            self.block = threading.Event()

        def load(self):
            self.block.wait(30)         # wedged: never answers
            return {}

    manager = FleetManager(period=0.05, probe_timeout=0.1)
    wedged = WedgedClient()
    manager.add_shard(wedged, shard_id="shard-wedge")
    handle = manager._shards["shard-wedge"]
    t0 = time.monotonic()
    manager.tick()
    # the tick returned promptly (deadline, not the 30s hang)...
    assert time.monotonic() - t0 < 5.0
    # ...and the timed-out probe counted as a failed probe
    assert handle.probe_timeouts >= 1
    assert handle.probe_failures >= 1
    assert manager.stats["probe_timeouts"] >= 1
    deadline = time.monotonic() + 10
    while manager.stats["dead_shards"] < 1:
        assert time.monotonic() < deadline, "wedged shard never died"
        time.sleep(0.05)
        manager.tick()
    assert manager.registry.state("shard-wedge") == S_DEAD
    wedged.block.set()                  # unwedge the probe threads


def test_heartbeat_errors_audited_with_bounded_dedupe():
    """S6: heartbeat failures must never be swallowed silently — the
    audit trail records the first occurrence and every 32nd repeat,
    with a bounded per-error counter; close() joins the beat thread."""
    manager, _ = _inproc_fleet(1)
    fc = FleetClient(manager, heartbeat=False)
    for _ in range(64):
        fc._audit_beat_error(RuntimeError("boom"))
    assert fc.beat_errors() == {"RuntimeError: boom": 64}
    audited = [e for e in fc.events if e["event"] == "beat_error"]
    assert [e["count"] for e in audited] == [1, 32, 64]
    # the error-key table is bounded: distinct errors evict the oldest
    for i in range(40):
        fc._audit_beat_error(ValueError(f"e{i}"))
    assert len(fc.beat_errors()) <= 32
    t0 = time.monotonic()
    fc.close()
    assert time.monotonic() - t0 < 5.0

    # end-to-end: a live beat thread whose manager edge is partitioned
    # lands the failure in the audit trail instead of dropping it
    from repro.core.faults import FaultPlan
    plan = FaultPlan(seed=1)
    plan.partition("w-audit", "manager", at=0)
    plan.tick()
    fc2 = FleetClient(manager, worker_id="w-audit", heartbeat=False,
                      fault_plan=plan)
    with pytest.raises(Exception):
        fc2.beat()
    fc2._hb_thread = threading.Thread(target=fc2._beat_loop, daemon=True)
    fc2._period = 0.02
    fc2._hb_thread.start()
    deadline = time.monotonic() + 5
    while not fc2.beat_errors():
        assert time.monotonic() < deadline, "beat error never audited"
        time.sleep(0.02)
    assert any("InjectedPartition" in k or "unreachable" in k
               for k in fc2.beat_errors())
    fc2.close()
