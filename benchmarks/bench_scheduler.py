"""Scheduler throughput + straggler mitigation effect."""
import tempfile
import time

import numpy as np

from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)
from repro.core.faults import FaultPolicy, wrap_trial


def throughput(parallel, budget=40):
    orch = Orchestrator(tempfile.mkdtemp())
    cfg = ExperimentConfig(name="thr", budget=budget, parallel=parallel,
                           optimizer="random",
                           space=Space([Param("x", "double", 0, 1)]))
    t0 = time.time()
    orch.run(cfg, trial_fn=lambda a, ctx: a["x"])
    dt = time.time() - t0
    return budget / dt, dt / budget * 1e6


def throughput_rows(parallels=(1, 8, 32), budget=40):
    """[(parallel, us_per_trial, trials_per_s)] for the JSON harness.
    A small warm-up run first so one-time import/jit cost doesn't land on
    the first measured row."""
    throughput(2, budget=4)
    return [(p, us, tps) for p in parallels
            for tps, us in [throughput(p, budget)]]


def straggler_effect(speculate):
    orch = Orchestrator(tempfile.mkdtemp())

    def trial(a, ctx):
        slow = a["x"] > 0.9                    # ~10% stragglers
        t_end = time.time() + (0.6 if slow else 0.02)
        while time.time() < t_end:
            time.sleep(0.01)
            ctx.report(1, 0.0)                 # cancellable
        return a["x"]

    cfg = ExperimentConfig(
        name="strag", budget=24, parallel=6, optimizer="sobol",
        space=Space([Param("x", "double", 0, 1)]),
        straggler_factor=3.0 if speculate else 0.0)
    t0 = time.time()
    orch.run(cfg, trial_fn=trial)
    return time.time() - t0


def main():
    print("# scheduler throughput (no-op trials)")
    print("name,us_per_call,derived")
    for p in (1, 8, 32):
        tps, us = throughput(p)
        print(f"bench_scheduler/throughput/p{p},{us:.0f},{tps:.0f} trials/s")
    base = straggler_effect(False)
    spec = straggler_effect(True)
    print(f"bench_scheduler/straggler/no_speculation,{base * 1e6 / 24:.0f},"
          f"wall={base:.2f}s")
    print(f"bench_scheduler/straggler/speculation,{spec * 1e6 / 24:.0f},"
          f"wall={spec:.2f}s speedup={base / spec:.2f}x")


if __name__ == "__main__":
    main()
