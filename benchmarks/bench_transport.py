"""Transport-plane benchmarks: the batched wire protocol's hot path.

Rows (wired into ``benchmarks/run.py collect()``, gated by
``scripts/bench_check.py``):

* ``bench_transport/observe_stream/c8`` — 8 workers streaming
  fire-and-forget observes through write-behind ``HTTPClient(batch=True)``
  clients against one service process; amortized µs per observe.
* ``bench_transport/report_http`` — one trial-events loop streaming
  reports through a batched client (same early-stop config as
  ``bench_service/report_http``, so the two rows are directly
  comparable); amortized µs per report.  Rung-crossing reports block for
  their real decision; the below-rung majority rides the batch.

Both rows measure *chunks* (elapsed / chunk size), not single calls —
an enqueue alone would measure a dict append; the chunk includes the
flushes the stream actually pays.
"""
import tempfile
import threading
import time

import numpy as np

from repro.api import CreateExperiment, HTTPClient, serve_api
from repro.api.protocol import ObserveRequest, ReportRequest
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space


def _space():
    return Space([Param("x", "double", 0.0, 1.0)])


def run_observe_stream(c=8, per=200, chunk=25):
    """[(row, us_samples)] — concurrent batched observe streams."""
    server = serve_api(tempfile.mkdtemp()).start()
    samples, lock = [], threading.Lock()
    try:
        cfg = ExperimentConfig(name="bench-obs", budget=c * per + 64,
                               parallel=c, optimizer="random",
                               space=_space())
        boot = HTTPClient(server.url)
        exp = boot.create_experiment(
            CreateExperiment(config=cfg.to_json())).exp_id
        boot.close()
        barrier = threading.Barrier(c)

        def worker(w):
            client = HTTPClient(server.url, batch=True)
            rng = np.random.default_rng(w)
            client.status(exp)          # keep-alive + queue drain warm
            barrier.wait()
            got = []
            for base in range(0, per, chunk):
                t0 = time.perf_counter()
                for j in range(base, min(base + chunk, per)):
                    client.observe(ObserveRequest(
                        exp, f"w{w}-s{j:05d}", {"x": float(rng.uniform())},
                        float(rng.normal())))
                client.flush()
                got.append((time.perf_counter() - t0) / chunk * 1e6)
            client.close()
            with lock:
                samples.extend(got)

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(c)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        server.shutdown()
    return [(f"observe_stream/c{c}", samples)]


def run_report_stream(n=400, chunk=50):
    """[(row, us_samples)] — batched trial-events stream (cf. the
    unbatched ``bench_service/report_http`` row)."""
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        client = HTTPClient(server.url, batch=True)
        exp = client.create_experiment(CreateExperiment(
            config=ExperimentConfig(
                name="bench-report", budget=10, parallel=1,
                optimizer="random", space=_space(),
                early_stop={"min_steps": 1, "eta": 3}).to_json())).exp_id
        client.report(ReportRequest(exp, "t0001", 1, 0.5))       # warm
        samples = []
        for base in range(0, n, chunk):
            t0 = time.perf_counter()
            for i in range(base, min(base + chunk, n)):
                client.report(ReportRequest(exp, "t0001", 2 + i, 0.5))
            client.flush()
            samples.append((time.perf_counter() - t0) / chunk * 1e6)
        client.close()
    finally:
        server.shutdown()
    return [("report_http", samples)]


def run(quick=False):
    rows = []
    rows.extend(run_observe_stream(per=100 if quick else 200))
    rows.extend(run_report_stream(n=200 if quick else 400))
    return rows


def main():
    med = lambda s: float(np.percentile(s, 50))      # noqa: E731
    print("# batched transport plane (p50 of chunk-amortized samples)")
    print("row,us_per_op")
    for suffix, us in run():
        print(f"bench_transport/{suffix},{med(us):.1f}")


if __name__ == "__main__":
    main()
