"""Fleet SLO: multi-tenant suggest latency through the router.

k experiments sharded across an HTTP fleet, c concurrent clients each
hammering every experiment round-robin — the paper's "many users, one
service" deployment.  The committed row is the p50 of per-call suggest
latency (an SLO row: a contended median, not a best case); p90 rides
along in the stats spread.  Everything crosses real HTTP twice (client →
shard) with the manager off the hot path, so a routing regression — map
lookups under the client lock, per-call map refreshes, admission checks
leaking into suggest — shows up here and nowhere else.
"""
import tempfile
import threading
import time

import numpy as np

from repro.api.protocol import CreateExperiment, ObserveRequest
from repro.core import ExperimentConfig, Param, Space
from repro.fleet import FleetClient, serve_fleet


def _cfg_json(name, budget):
    cfg = ExperimentConfig(name=name, budget=budget, optimizer="random",
                           space=Space([Param("x", "double", 0, 1)]))
    return dict(cfg.to_json())


def run(k=8, clients=4, calls=25, shards=2, period=5.0):
    """Returns [(row_suffix, [us, ...])] — one sample per suggest call
    across all clients.  ``calls`` is per client per experiment; budget is
    sized so headroom never throttles the bench."""
    root = tempfile.mkdtemp()
    srv = serve_fleet(root, shards=shards, period=period).start()
    samples = []
    lock = threading.Lock()
    try:
        boss = FleetClient(srv.url, heartbeat=False)
        budget = 2 * clients * calls + 8
        exp_ids = [boss.create_experiment(CreateExperiment(
            config=_cfg_json(f"slo-{i}", budget),
            exp_id=f"exp-slo-{i:02d}")).exp_id for i in range(k)]

        def client_loop(ci):
            cl = FleetClient(srv.url, worker_id=f"bench-{ci}",
                             heartbeat=False)
            mine = []
            for _ in range(calls):
                for eid in exp_ids:
                    t0 = time.perf_counter()
                    batch = cl.suggest(eid, 1)
                    mine.append((time.perf_counter() - t0) * 1e6)
                    for s in batch.suggestions:
                        cl.observe(ObserveRequest(eid, s.suggestion_id,
                                                  s.assignment, value=0.5))
            cl.close()
            with lock:
                samples.extend(mine)

        threads = [threading.Thread(target=client_loop, args=(ci,))
                   for ci in range(clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        boss.close()
    finally:
        srv.shutdown()
    return [(f"suggest/k{k}c{clients}", samples)]


def run_rebalance(k=8, calls=40, shards=2, period=5.0):
    """Suggest latency *during a live shard-add rebalance* (ungated row:
    tracked, not gated — rebalance cost is environment-sensitive).

    One client hammers k experiments round-robin; a third of the way in,
    a freshly-spawned shard joins via ``POST /fleet/shards`` and the
    manager drains/adopts/transfers the minimal disruption set while the
    client keeps calling.  Returned samples start at the add trigger, so
    the committed p90 is the SLO "how slow does suggest get while the
    fleet is rebalancing under you".
    """
    from repro.api.http import HTTPClient, serve_api

    root = tempfile.mkdtemp()
    srv = serve_fleet(root, shards=shards, period=period).start()
    extra = None
    try:
        boss = FleetClient(srv.url, heartbeat=False)
        budget = 2 * calls + 8
        exp_ids = [boss.create_experiment(CreateExperiment(
            config=_cfg_json(f"rb-{i}", budget),
            exp_id=f"exp-rbb-{i:02d}")).exp_id for i in range(k)]
        extra = serve_api(root).start()
        mgr_http = HTTPClient(srv.url)
        trigger = threading.Event()

        def add_shard():
            trigger.wait(30)
            mgr_http._call("POST", "/fleet/shards",
                           {"url": extra.url, "shard_id": "shard-add"})

        adder = threading.Thread(target=add_shard, daemon=True)
        adder.start()
        cl = FleetClient(srv.url, worker_id="bench-rb", heartbeat=False)
        samples = []
        for n in range(calls):
            if n == calls // 3:
                trigger.set()
            for eid in exp_ids:
                t0 = time.perf_counter()
                batch = cl.suggest(eid, 1)
                dt = (time.perf_counter() - t0) * 1e6
                if trigger.is_set():
                    samples.append(dt)
                for s in batch.suggestions:
                    cl.observe(ObserveRequest(eid, s.suggestion_id,
                                              s.assignment, value=0.5))
        adder.join(timeout=30)
        cl.close()
        mgr_http.close()
        boss.close()
    finally:
        srv.shutdown()
        if extra is not None:
            extra.shutdown()
    return [(f"rebalance/k{k}", samples)]


def main():
    print("# fleet suggest-latency SLO (k experiments x c clients, "
          "HTTP router)")
    print("row,p50_us,p90_us,n")
    for suffix, us in run():
        print(f"bench_fleet/{suffix},{np.percentile(us, 50):.0f},"
              f"{np.percentile(us, 90):.0f},{len(us)}")
    for suffix, us in run_rebalance():
        print(f"bench_fleet/{suffix},{np.percentile(us, 50):.0f},"
              f"{np.percentile(us, 90):.0f},{len(us)}")


if __name__ == "__main__":
    main()
