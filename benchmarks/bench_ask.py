"""Serial vs batched cross-experiment q-EI ask cost (ISSUE 10).

Measures the per-ask wall cost of k concurrent experiments' speculative
refill selections at the h=50 operating point (shape bucket 64, pool of
640 candidates, ``ASK_CHUNK=8`` picks per ask — exactly what a pump's
``_ask_lane`` snapshots in steady state):

* ``serial/k8``   — k independent ``gp.select_batch`` calls, one per
  experiment (the pre-ISSUE-10 refill path: one greedy q-EI dispatch
  per experiment).
* ``batched/k8``  — ONE ``gp.batched_select`` dispatch scanning all k
  lanes' constant-liar picks together (what the executor's ask gather
  runs when k pumps' refill demand lands in one gather window).
* ``batched/k32`` — same at 32 lanes, where the per-dispatch fixed
  overhead amortizes furthest.

Rows are µs **per ask** (one ask = one experiment's 8-point selection)
so the serial/batched ratio reads directly as the throughput speedup.
On a single-core CPU host the win is bounded by per-dispatch Python +
XLA launch overhead; the vmap'd scan exists for per-device batching on
TPU, where lanes share the fused Pallas EI kernel (see API.md
§Ask batching).
"""
import time

import jax
import numpy as np

from repro.core.suggest import gp

H = 50          # history size -> bucket 64
D = 4
M = 640         # candidate pool size (BayesOpt default n_candidates*1.25)
N_ASK = 8       # picks per ask (pipeline.ASK_CHUNK)
BUCKET = 64


def _experiments(k, seed=0):
    """k experiments' (posterior, candidate pool, incumbent) at h=50."""
    rng = np.random.default_rng(seed)
    items = []
    for i in range(k):
        x = rng.random((H, D))
        w = rng.random(D)
        y = np.sin(3.0 * x @ w) + 0.1 * rng.standard_normal(H)
        post = gp.fit_gp(x, y, steps=8, bucket=BUCKET)
        cand = rng.random((M, D)).astype(np.float32)
        items.append((post, cand, float(y.max()), N_ASK))
    return items


def run(reps=5, quick=False):
    """Yield (row_suffix, samples) with samples in µs per ask."""
    if quick:
        reps = 3
    widths = (8, 32)
    items = _experiments(max(widths))
    # pay every compile up front (select_batch's (bucket, k_pad) scan +
    # batched_select's (bucket, k_pad, lane-pad) lanes) so rows measure
    # steady state
    post, cand, best, n = items[0]
    gp.select_batch(post, cand, best, n)
    for k in widths:
        gp.batched_select(items[:k])

    serial = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for post, cand, best, n in items[:8]:
            picks, _ = gp.select_batch(post, cand, best, n)
            # select_batch dispatches async — block or the row measures
            # enqueue
            jax.block_until_ready(picks)
        serial.append((time.perf_counter() - t0) / 8 * 1e6)
    yield "serial/k8", serial

    for k in widths:
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            gp.batched_select(items[:k])   # blocks on picks internally
            samples.append((time.perf_counter() - t0) / k * 1e6)
        yield f"batched/k{k}", samples


def main():
    print("row,us_per_ask,speedup_vs_serial")
    base = None
    for suffix, samples in run():
        us = min(samples)
        if suffix == "serial/k8":
            base = us
        ratio = f"{base / us:.2f}" if base else ""
        print(f"bench_ask/{suffix},{us:.0f},{ratio}")


if __name__ == "__main__":
    main()
