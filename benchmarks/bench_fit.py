"""Serial vs batched cross-experiment GP hyperfit cost (ISSUE 8).

Measures the per-fit wall cost of k concurrent experiments' deferred
hyperparameter refits at the h=50 operating point (shape bucket 64,
warm-start Adam, ``warm_fit_steps=40`` — exactly what the adaptive
schedule runs in steady state):

* ``serial/k8``   — k independent ``gp.fit_gp`` calls, one per
  experiment (the pre-ISSUE-8 FitExecutor path: one dispatch per fit).
* ``batched/k8``  — ONE ``gp.batched_fit`` dispatch fitting all k lanes
  through the vmap'd masked Adam loop (what the executor's co-batching
  path runs when k experiments' debt lands in one gather window).
* ``batched/k32`` — same at the ``FIT_LANES_MAX`` width, where the
  per-dispatch fixed overhead amortizes furthest.

Rows are µs **per fit** so the serial/batched ratio reads directly as
the throughput speedup.  On a single-core CPU host the win is bounded
by LAPACK per-lane call overhead (measured ~1.7-2x here); the vmap'd
dispatch exists for per-device batching on TPU, where lanes share the
fused Pallas NLL kernel (see API.md §Fit batching).
"""
import time

import jax
import numpy as np

from repro.core.suggest import gp

H = 50          # history size -> bucket 64
D = 4
STEPS = 40      # BayesOpt.warm_fit_steps at h=50 (see _warm_steps_at)
BUCKET = 64


def _experiments(k, seed=0):
    """k experiments' (x, y, warm params0) at h=50, warm-started the way
    the pump would (a prior fit's params seed the next warm fit)."""
    rng = np.random.default_rng(seed)
    items = []
    for i in range(k):
        x = rng.random((H, D))
        w = rng.random(D)
        y = np.sin(3.0 * x @ w) + 0.1 * rng.standard_normal(H)
        post = gp.fit_gp(x, y, steps=8, bucket=BUCKET)   # warm start
        items.append((x, y, post.params))
    return items


def run(reps=5, quick=False):
    """Yield (row_suffix, samples) with samples in µs per fit."""
    if quick:
        reps = 3
    widths = (8, 32)
    items = _experiments(max(widths))
    # pay every compile up front (fit_gp per-bucket jit + batched_fit's
    # (bucket, steps, k_pad) lanes) so rows measure steady state
    for x, y, p0 in items[:1]:
        gp.fit_gp(x, y, steps=STEPS, params0=p0, bucket=BUCKET)
    for k in widths:
        gp.batched_fit(items[:k], steps=STEPS, bucket=BUCKET)

    serial = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for x, y, p0 in items[:8]:
            post = gp.fit_gp(x, y, steps=STEPS, params0=p0, bucket=BUCKET)
            # fit_gp dispatches async — block or the row measures enqueue
            jax.block_until_ready(post.chol)
        serial.append((time.perf_counter() - t0) / 8 * 1e6)
    yield "serial/k8", serial

    for k in widths:
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            gp.batched_fit(items[:k], steps=STEPS, bucket=BUCKET)
            samples.append((time.perf_counter() - t0) / k * 1e6)
        yield f"batched/k{k}", samples


def main():
    print("row,us_per_fit,speedup_vs_serial")
    base = None
    for suffix, samples in run():
        us = min(samples)
        if suffix == "serial/k8":
            base = us
        ratio = f"{base / us:.2f}" if base else ""
        print(f"bench_fit/{suffix},{us:.0f},{ratio}")


if __name__ == "__main__":
    main()
