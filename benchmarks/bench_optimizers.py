"""Optimizer quality: best-found after a fixed budget on benchmark
functions (paper cites grid/random/evolutionary/swarm/Bayesian as suitable
strategies — this table compares them under identical budgets)."""
import numpy as np

from repro.core.space import Param, Space
from repro.core.suggest import Observation, make_optimizer


def branin(a):
    x = a["x"] * 15 - 5
    y = a["y"] * 15
    v = ((y - 5.1 / (4 * np.pi ** 2) * x ** 2 + 5 / np.pi * x - 6) ** 2
         + 10 * (1 - 1 / (8 * np.pi)) * np.cos(x) + 10)
    return -v      # maximize


def lr_valley(a):
    return -((np.log10(a["lr"]) + 2.7) ** 2 + 3 * (a["m"] - 0.9) ** 2)


FUNCS = {
    "branin": (branin, Space([Param("x", "double", 0, 1),
                              Param("y", "double", 0, 1)])),
    "lr_valley": (lr_valley, Space([Param("lr", "double", 1e-5, 1e-1,
                                          log=True),
                                    Param("m", "double", 0.0, 0.99)])),
}
NAMES = ["random", "grid", "sobol", "evolution", "pso", "gp"]


def run(budget=40, batch=4, seeds=(0, 1, 2)):
    rows = []
    for fname, (f, space) in FUNCS.items():
        for name in NAMES:
            bests = []
            for seed in seeds:
                opt = make_optimizer(name, space, seed=seed)
                for _ in range(budget // batch):
                    asks = opt.ask(batch)
                    obs = []
                    for a in asks:
                        clean = {k: v for k, v in a.items()
                                 if not k.startswith("__")}
                        obs.append(Observation(
                            clean, f(clean),
                            metadata={k: v for k, v in a.items()
                                      if k.startswith("__")}))
                    opt.tell(obs)
                bests.append(opt.best().value)
            rows.append((fname, name, float(np.mean(bests)),
                         float(np.std(bests))))
    return rows


def main():
    print("# optimizer quality, best after 40 evals (mean over 3 seeds)")
    print("function/optimizer,us_per_call,best_mean,best_std")
    for fname, name, mean, std in run():
        print(f"bench_optimizers/{fname}/{name},0,{mean:.4f},{std:.4f}")


if __name__ == "__main__":
    main()
