"""Benchmark harness: one section per paper claim/figure + the roofline
readout.  Prints ``name,us_per_call,derived`` CSV rows (see DESIGN.md §9
for the experiment index)."""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_optimizers, bench_parallel,
                            bench_population, bench_roofline,
                            bench_scheduler, bench_suggest_latency)
    for mod in (bench_parallel, bench_optimizers, bench_suggest_latency,
                bench_scheduler, bench_population, bench_roofline):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},FAILED,")


if __name__ == "__main__":
    main()
