"""Benchmark harness.

Two modes:

* ``python -m benchmarks.run`` — legacy CSV: one section per paper
  claim/figure + the roofline readout, ``name,us_per_call,derived`` rows.
* ``python -m benchmarks.run --json [FILE] [--quick]`` — machine-readable
  perf trajectory: runs the suggestion/service/scheduler hot-path benches
  and writes ``BENCH_suggest.json`` (schema below), so speedups and
  regressions are tracked across PRs.  ``--quick`` shrinks history sizes
  and repetitions for CI (the tier-2 perf gate — see scripts/bench_check.py
  and ROADMAP.md).

Row reduction (ISSUE 5): every suggest/service bench collects *per-call
samples*; the gated scalar in ``rows`` is the **min of k** samples for
single-path rows (the true cost of the operation — a CPU-contention
hiccup in one call can no longer inflate a committed row ~2x), the
**mean** for the ``*_cycle`` rows (their point is amortizing the
periodic hyperfit — a min would always pick a refit-free cycle), and
the **p50** for the ``suggest_contended_*`` rows (a contended row's
value IS its median; its min is just a queue hit).  The per-row p50/p90
spread is kept alongside in ``stats`` so bimodality stays visible in
the committed baseline.

JSON schema::

  {"schema": 2, "unit": "us", "created": <epoch>, "quick": bool,
   "rows": {"bench_suggest/gp/h150": 7600.0, ...},
   "stats": {"bench_suggest/gp/h150": {"p50": ..., "p90": ..., "n": 10}}}

Schema 1 (scalar rows only, no ``stats``) is still read by
``scripts/bench_check.py`` baselines.
"""
import argparse
import json
import sys
import time
import traceback

import numpy as np


def _reduce(rows, stats, name, samples, gate="min"):
    """Fold one bench's sample list into the gate scalar + p50/p90."""
    samples = list(samples)
    if gate == "min":
        value = min(samples)
    elif gate == "mean":
        # trimmed: drop the single worst sample (a one-off XLA compile or
        # scheduler hiccup would otherwise dominate a small-k mean) while
        # still averaging the genuine periodic-refit share
        kept = sorted(samples)[:-1] if len(samples) >= 8 else samples
        value = sum(kept) / len(kept)
    elif gate == "p90":
        value = float(np.percentile(samples, 90))
    else:
        value = float(np.percentile(samples, 50))
    rows[name] = round(value, 1)
    stats[name] = {"p50": round(float(np.percentile(samples, 50)), 1),
                   "p90": round(float(np.percentile(samples, 90)), 1),
                   "n": len(samples)}


def collect(quick: bool = False) -> dict:
    """Hot-path rows only (suggest / service / scheduler) — the tracked
    perf surface.  Returns {"rows": {row: us}, "stats": {row: spread}}."""
    from benchmarks import bench_scheduler, bench_suggest_latency
    rows, stats = {}, {}
    hist = (10, 50) if quick else (10, 50, 150)
    names = (("random", "gp") if quick
             else ("random", "sobol", "evolution", "pso", "gp"))
    for name, h, us in bench_suggest_latency.run(history_sizes=hist,
                                                 names=names):
        _reduce(rows, stats, f"bench_suggest/{name}/h{h}", us)
    for name, h, us in bench_suggest_latency.run_batched(history_sizes=hist):
        _reduce(rows, stats, f"bench_suggest/{name}_batch8/h{h}", us)
    for name, h, us in bench_suggest_latency.run_cycle(history_sizes=hist):
        # the cycle row exists to amortize the periodic hyperfit into the
        # steady-state cost — min-of-k would always pick a refit-free
        # cycle and a refit regression could never fail the gate
        _reduce(rows, stats, f"bench_suggest/{name}_cycle/h{h}", us,
                gate="mean")
    for backend, us in bench_suggest_latency.run_service(
            n=20 if quick else 100):
        _reduce(rows, stats, f"bench_service/{backend}", us)
    for backend, us in bench_suggest_latency.run_report(
            n=50 if quick else 200):
        _reduce(rows, stats, f"bench_service/{backend}", us)
    for name, us in bench_suggest_latency.run_contended(
            calls=4 if quick else 8, seed_obs=24 if quick else 40):
        # a contended row is its median by definition (min = queue hit)
        _reduce(rows, stats, f"bench_service/{name}", us, gate="p50")
    for p, us, tps in bench_scheduler.throughput_rows(
            parallels=(8,) if quick else (1, 8, 32),
            budget=20 if quick else 40):
        rows[f"bench_scheduler/throughput/p{p}"] = round(us, 1)
    from benchmarks import bench_fleet
    for suffix, us in bench_fleet.run(calls=8 if quick else 25):
        # an SLO row: the gate is the contended median, not a best case
        _reduce(rows, stats, f"bench_fleet/{suffix}", us, gate="p50")
    for suffix, us in bench_fleet.run_rebalance(calls=15 if quick else 40):
        # tracked-not-gated (scripts/bench_check.py UNGATED_ROWS): the
        # tail during a live shard-add rebalance is the row's point, so
        # commit the p90
        _reduce(rows, stats, f"bench_fleet/{suffix}", us, gate="p90")
    from benchmarks import bench_transport
    for suffix, us in bench_transport.run(quick=quick):
        # chunk-amortized stream rows: the p50 chunk is the steady state
        # (a min chunk would just be one that dodged every flush)
        _reduce(rows, stats, f"bench_transport/{suffix}", us, gate="p50")
    from benchmarks import bench_fit
    for suffix, us in bench_fit.run(quick=quick):
        # serial vs batched cross-experiment hyperfit cost (ISSUE 8):
        # µs per fit, so batched/serial reads as the throughput ratio
        _reduce(rows, stats, f"bench_fit/{suffix}", us)
    from benchmarks import bench_ask
    for suffix, us in bench_ask.run(quick=quick):
        # serial vs batched cross-experiment q-EI ask cost (ISSUE 10):
        # µs per ask, so batched/serial reads as the throughput ratio
        _reduce(rows, stats, f"bench_ask/{suffix}", us)
    return {"rows": rows, "stats": stats}


def write_json(path: str, quick: bool = False) -> dict:
    collected = collect(quick=quick)
    payload = {"schema": 2, "unit": "us", "created": time.time(),
               "quick": quick, "rows": collected["rows"],
               "stats": collected["stats"]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_suggest.json",
                    default=None, metavar="FILE",
                    help="write machine-readable rows to FILE "
                         "(default BENCH_suggest.json) instead of CSV")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep for CI perf gating")
    args = ap.parse_args(argv)

    if args.json:
        payload = write_json(args.json, quick=args.quick)
        for name, us in sorted(payload["rows"].items()):
            spread = payload["stats"].get(name)
            tail = (f",p50={spread['p50']:.0f},p90={spread['p90']:.0f}"
                    if spread else "")
            print(f"{name},{us:.0f}{tail}")
        print(f"wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)
        return

    from benchmarks import (bench_ask, bench_fit, bench_fleet,
                            bench_optimizers, bench_parallel,
                            bench_population, bench_roofline,
                            bench_scheduler, bench_suggest_latency)
    for mod in (bench_parallel, bench_optimizers, bench_suggest_latency,
                bench_fit, bench_ask, bench_scheduler, bench_fleet,
                bench_population, bench_roofline):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},FAILED,")


if __name__ == "__main__":
    main()
