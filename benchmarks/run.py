"""Benchmark harness.

Two modes:

* ``python -m benchmarks.run`` — legacy CSV: one section per paper
  claim/figure + the roofline readout, ``name,us_per_call,derived`` rows.
* ``python -m benchmarks.run --json [FILE] [--quick]`` — machine-readable
  perf trajectory: runs the suggestion/service/scheduler hot-path benches
  and writes ``BENCH_suggest.json`` (schema below), so speedups and
  regressions are tracked across PRs.  ``--quick`` shrinks history sizes
  and repetitions for CI (the tier-2 perf gate — see scripts/bench_check.py
  and ROADMAP.md).

JSON schema::

  {"schema": 1, "unit": "us", "created": <epoch>, "quick": bool,
   "rows": {"bench_suggest/gp/h150": 7600.0, ...}}
"""
import argparse
import json
import sys
import time
import traceback


def collect(quick: bool = False) -> dict:
    """Hot-path rows only (suggest / service / scheduler) — the tracked
    perf surface.  Returns {row_name: us}."""
    from benchmarks import bench_scheduler, bench_suggest_latency
    rows = {}
    hist = (10, 50) if quick else (10, 50, 150)
    names = (("random", "gp") if quick
             else ("random", "sobol", "evolution", "pso", "gp"))
    for name, h, us in bench_suggest_latency.run(history_sizes=hist,
                                                 names=names):
        rows[f"bench_suggest/{name}/h{h}"] = round(us, 1)
    for name, h, us in bench_suggest_latency.run_batched(history_sizes=hist):
        rows[f"bench_suggest/{name}_batch8/h{h}"] = round(us, 1)
    for name, h, us in bench_suggest_latency.run_cycle(history_sizes=hist):
        rows[f"bench_suggest/{name}_cycle/h{h}"] = round(us, 1)
    for backend, us in bench_suggest_latency.run_service(
            n=20 if quick else 100):
        rows[f"bench_service/{backend}"] = round(us, 1)
    for backend, us in bench_suggest_latency.run_report(
            n=50 if quick else 200):
        rows[f"bench_service/{backend}"] = round(us, 1)
    for name, us in bench_suggest_latency.run_contended(
            calls=4 if quick else 8, seed_obs=24 if quick else 40):
        rows[f"bench_service/{name}"] = round(us, 1)
    for p, us, tps in bench_scheduler.throughput_rows(
            parallels=(8,) if quick else (1, 8, 32),
            budget=20 if quick else 40):
        rows[f"bench_scheduler/throughput/p{p}"] = round(us, 1)
    return rows


def write_json(path: str, quick: bool = False) -> dict:
    payload = {"schema": 1, "unit": "us", "created": time.time(),
               "quick": quick, "rows": collect(quick=quick)}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_suggest.json",
                    default=None, metavar="FILE",
                    help="write machine-readable rows to FILE "
                         "(default BENCH_suggest.json) instead of CSV")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep for CI perf gating")
    args = ap.parse_args(argv)

    if args.json:
        payload = write_json(args.json, quick=args.quick)
        for name, us in sorted(payload["rows"].items()):
            print(f"{name},{us:.0f}")
        print(f"wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)
        return

    from benchmarks import (bench_optimizers, bench_parallel,
                            bench_population, bench_roofline,
                            bench_scheduler, bench_suggest_latency)
    for mod in (bench_parallel, bench_optimizers, bench_suggest_latency,
                bench_scheduler, bench_population, bench_roofline):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},FAILED,")


if __name__ == "__main__":
    main()
