"""Population (vmap) trial throughput vs sequential execution of the same
trials — the TPU-native '15 models simultaneously' (on CPU the win is
batching overhead amortization; on TPU the MXU batches the matmuls)."""
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.vmap_trials import PopulationTrainer
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig


def run(trials=8, steps=10):
    cfg = get_config("granite-8b").reduced(n_layers=2)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=2))
    data = lambda t: {k: jnp.asarray(v) for k, v in pipe.batch_at(t).items()}
    rng = np.random.default_rng(0)
    assigns = [{"lr": float(10 ** rng.uniform(-4, -2)), "seed": i}
               for i in range(trials)]

    trainer = PopulationTrainer(cfg, AdamWConfig())
    trainer.train(assigns[:1], data, steps=2)        # warm compile (P=1)
    t0 = time.time()
    for a in assigns:                                # sequential: P programs
        trainer.train([a], data, steps=steps)
    seq = time.time() - t0

    trainer.train(assigns, data, steps=2)            # warm compile (P=n)
    t0 = time.time()
    trainer.train(assigns, data, steps=steps)
    pop = time.time() - t0
    return seq, pop


def main():
    trials, steps = 8, 10
    seq, pop = run(trials, steps)
    print("# population vmap vs sequential (same trials)")
    print("name,us_per_call,derived")
    print(f"bench_population/sequential,{seq * 1e6 / (trials * steps):.0f},"
          f"wall={seq:.2f}s")
    print(f"bench_population/vmap,{pop * 1e6 / (trials * steps):.0f},"
          f"wall={pop:.2f}s speedup={seq / pop:.2f}x")


if __name__ == "__main__":
    main()
