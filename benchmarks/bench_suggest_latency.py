"""Suggestion-service latency.

Three sections:
* us per raw ``ask()`` at growing history sizes — the optimizer hot path;
* us per point for a batched ``ask(8)`` (the constant-liar q-EI pass the
  scheduler actually uses to fill its parallel slots);
* us per full suggest→observe round trip through the service API
  (``LocalClient`` in-process vs the HTTP backend) — the overhead the
  scheduler/worker loop actually pays per observation (API.md §Overhead).

Each ``run*`` function returns structured rows; ``benchmarks/run.py
--json`` aggregates them into ``BENCH_suggest.json``.
"""
import tempfile
import time

import numpy as np

from repro.api import CreateExperiment, HTTPClient, LocalClient, \
    ObserveRequest, ReportRequest, serve_api
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space, strip_internal
from repro.core.suggest import Observation, make_optimizer


def _space():
    return Space([Param("a", "double", 0, 1),
                  Param("b", "double", 1e-4, 1, log=True),
                  Param("c", "int", 1, 64)])


def _seeded(name, h, rng):
    space = _space()
    opt = make_optimizer(name, space, seed=0)
    obs = [Observation(a, float(rng.normal()))
           for a in space.sample(rng, h)]
    opt.tell(obs)
    return opt


def run(history_sizes=(10, 50, 150), names=("random", "sobol", "evolution",
                                            "pso", "gp")):
    """[(optimizer, history, us_per_ask1)] — sequential ask(1) hot path."""
    rng = np.random.default_rng(0)
    rows = []
    for name in names:
        for h in history_sizes:
            opt = _seeded(name, h, rng)
            opt.ask(1)                      # warm caches / jit
            t0 = time.perf_counter()
            n = 10
            for _ in range(n):
                opt.ask(1)
            us = (time.perf_counter() - t0) / n * 1e6
            rows.append((name, h, us))
    return rows


def run_cycle(history_sizes=(10, 50, 150), names=("gp",)):
    """[(optimizer, history, us_per_cycle)] for a tell(1)+ask(1) cycle —
    the scheduler's steady-state pattern, which (for GP) pays one
    warm-started hyperparameter fit per ask."""
    rng = np.random.default_rng(0)
    space = _space()
    rows = []
    for name in names:
        for h in history_sizes:
            opt = _seeded(name, h, rng)

            def observe(a, value):
                meta = {k: v for k, v in a.items() if k.startswith("__")}
                opt.tell([Observation(strip_internal(a), value,
                                      metadata=meta)])

            a = opt.ask(1)[0]           # warm the cold-fit path
            observe(a, 0.0)
            a = opt.ask(1)[0]           # warm the warm-fit path (jit)
            t0 = time.perf_counter()
            n = 8
            for _ in range(n):
                observe(a, float(rng.normal()))
                a = opt.ask(1)[0]
            us = (time.perf_counter() - t0) / n * 1e6
            rows.append((name, h, us))
    return rows


def run_batched(history_sizes=(10, 50, 150), batch=8, names=("gp",)):
    """[(optimizer, history, us_per_point)] for a single ask(batch) — the
    parallel-slot-filling path (one fit + one jitted q-EI scan for GP)."""
    rng = np.random.default_rng(0)
    rows = []
    for name in names:
        for h in history_sizes:
            opt = _seeded(name, h, rng)
            opt.ask(batch)                  # warm caches / jit
            t0 = time.perf_counter()
            n = 3
            for _ in range(n):
                opt.ask(batch)
            us = (time.perf_counter() - t0) / (n * batch) * 1e6
            rows.append((name, h, us))
    return rows


def _roundtrips(client, n):
    """n suggest→observe round trips; returns us per round trip."""
    resp = client.create_experiment(CreateExperiment(config=ExperimentConfig(
        name="bench", budget=n + 10, parallel=1, optimizer="random",
        space=_space()).to_json()))
    exp = resp.exp_id
    # warm one full cycle (jit, connection setup)
    s = client.suggest(exp, 1).suggestions[0]
    client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment, 0.0))
    t0 = time.perf_counter()
    for i in range(n):
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
    return (time.perf_counter() - t0) / n * 1e6


def run_service(n=50):
    """Service overhead: [(backend, us_per_suggest_observe_roundtrip)]."""
    rows = [("local", _roundtrips(LocalClient(tempfile.mkdtemp()), n))]
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        rows.append(("http", _roundtrips(HTTPClient(server.url), n)))
    finally:
        server.shutdown()
    return rows


def _reports(client, n):
    """n ctx.report round trips (metric append + shared-ASHA decision);
    returns us per report."""
    exp = client.create_experiment(CreateExperiment(config=ExperimentConfig(
        name="bench-report", budget=10, parallel=1, optimizer="random",
        space=_space(),
        early_stop={"min_steps": 1, "eta": 3}).to_json())).exp_id
    client.report(ReportRequest(exp, "t0001", 1, 0.5))       # warm
    t0 = time.perf_counter()
    for i in range(n):
        client.report(ReportRequest(exp, "t0001", 2 + i, 0.5))
    return (time.perf_counter() - t0) / n * 1e6


def run_report(n=200):
    """Trial-events overhead: [(backend, us_per_report_roundtrip)] — the
    per-step cost a training loop pays for service-side early stopping."""
    rows = [("report_local", _reports(LocalClient(tempfile.mkdtemp()), n))]
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        rows.append(("report_http", _reports(HTTPClient(server.url), n)))
    finally:
        server.shutdown()
    return rows


def main():
    print("# ask() latency vs history size")
    print("optimizer/history,us_per_call")
    for name, h, us in run():
        print(f"bench_suggest/{name}/h{h},{us:.0f}")
    print("# batched ask(8), per point")
    for name, h, us in run_batched():
        print(f"bench_suggest/{name}_batch8/h{h},{us:.0f}")
    print("# tell(1)+ask(1) cycle (includes the warm hyperparameter fit)")
    for name, h, us in run_cycle():
        print(f"bench_suggest/{name}_cycle/h{h},{us:.0f}")
    print("# suggest+observe round trip through the service API")
    print("backend,us_per_roundtrip")
    for backend, us in run_service():
        print(f"bench_service/{backend},{us:.0f}")
    print("# trial-progress report round trip (metrics + ASHA decision)")
    for backend, us in run_report():
        print(f"bench_service/{backend},{us:.0f}")


if __name__ == "__main__":
    main()
