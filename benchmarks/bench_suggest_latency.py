"""Suggestion-service latency.

Four sections:
* us per raw ``ask()`` at growing history sizes — the optimizer hot path;
* us per point for a batched ``ask(8)`` (the constant-liar q-EI pass the
  scheduler actually uses to fill its parallel slots);
* us per full suggest→observe round trip through the service API
  (``LocalClient`` in-process vs the HTTP backend) — the overhead the
  scheduler/worker loop actually pays per observation (API.md §Overhead);
* p50 ``suggest`` latency under 1/8/32-way client contention with the
  suggestion pipeline on (and, as the comparison row, off) — the number
  that decides whether the service scales with scheduler parallelism.

Warmups call ``Optimizer.prewarm`` where available so the timed regions
measure steady-state latency, not first-touch XLA compiles — exactly what
a served experiment sees, since the service's prefetch pump prewarms the
shape buckets at creation (API.md §Suggestion pipeline).  Without this
the old `gp/h10` and `gp_batch8/h50` rows were dominated by a single
~0.7 s bucket-crossing compile inside the timed loop.

Each ``run*`` function returns structured rows whose value is the full
*sample list* (per-call/per-cycle µs), not a single mean: ``benchmarks/
run.py --json`` reduces them to a min-of-k gate value plus p50/p90
spread, so one CPU-contention hiccup inside a timed loop can no longer
inflate a committed row ~2× (ISSUE 5).
"""
import os
import tempfile
import threading
import time

import numpy as np

from repro.api import CreateExperiment, HTTPClient, LocalClient, \
    ObserveRequest, ReportRequest, serve_api
from repro.core.experiment import ExperimentConfig
from repro.core.space import Param, Space, strip_internal
from repro.core.suggest import Observation, make_optimizer


def _space():
    return Space([Param("a", "double", 0, 1),
                  Param("b", "double", 1e-4, 1, log=True),
                  Param("c", "int", 1, 64)])


def _seeded(name, h, rng, asks=16):
    space = _space()
    opt = make_optimizer(name, space, seed=0)
    obs = [Observation(a, float(rng.normal()))
           for a in space.sample(rng, h)]
    opt.tell(obs)
    # compile every bucket the timed asks can grow into (pending lies
    # accumulate), so the rows measure steady-state, not XLA compiles
    opt.prewarm(h + asks, batch=8)
    return opt


def run(history_sizes=(10, 50, 150), names=("random", "sobol", "evolution",
                                            "pso", "gp")):
    """[(optimizer, history, [us_per_ask1, ...])] — sequential ask(1) hot
    path, one sample per call."""
    rng = np.random.default_rng(0)
    rows = []
    for name in names:
        for h in history_sizes:
            opt = _seeded(name, h, rng)
            opt.ask(1)                      # warm caches / jit
            samples = []
            for _ in range(10):
                t0 = time.perf_counter()
                opt.ask(1)
                samples.append((time.perf_counter() - t0) * 1e6)
            rows.append((name, h, samples))
    return rows


def run_cycle(history_sizes=(10, 50, 150), names=("gp",)):
    """[(optimizer, history, us_per_cycle)] for a tell(1)+ask(1) cycle —
    the scheduler's steady-state pattern, which (for GP) pays one
    warm-started hyperparameter fit per ask."""
    rng = np.random.default_rng(0)
    space = _space()
    rows = []
    for name in names:
        for h in history_sizes:
            # asks: prewarm headroom past the 26 observes below, so the
            # timed cycles never cross into an uncompiled shape bucket
            opt = _seeded(name, h, rng, asks=40)

            def observe(a, value):
                meta = {k: v for k, v in a.items() if k.startswith("__")}
                opt.tell([Observation(strip_internal(a), value,
                                      metadata=meta)])

            a = opt.ask(1)[0]           # warm the cold-fit path
            observe(a, 0.0)
            a = opt.ask(1)[0]           # warm the warm-fit path (jit)
            samples = []
            # enough cycles that >=2 land on a hyperfit even at the
            # LONGEST adaptive refit period in the sweep (h150: every
            # ~9 obs), so the gate's trimmed mean — which drops one
            # worst sample — always retains a refit share
            for _ in range(24):
                t0 = time.perf_counter()
                observe(a, float(rng.normal()))
                a = opt.ask(1)[0]
                samples.append((time.perf_counter() - t0) * 1e6)
            rows.append((name, h, samples))
    return rows


def run_batched(history_sizes=(10, 50, 150), batch=8, names=("gp",)):
    """[(optimizer, history, us_per_point)] for a single ask(batch) — the
    parallel-slot-filling path (one fit + one jitted q-EI scan for GP)."""
    rng = np.random.default_rng(0)
    rows = []
    for name in names:
        for h in history_sizes:
            opt = _seeded(name, h, rng, asks=5 * batch)
            opt.ask(batch)                  # warm caches / jit
            samples = []
            for _ in range(3):
                t0 = time.perf_counter()
                opt.ask(batch)
                samples.append((time.perf_counter() - t0) / batch * 1e6)
            rows.append((name, h, samples))
    return rows


def _roundtrips(client, n):
    """n suggest→observe round trips; returns per-round-trip us samples."""
    resp = client.create_experiment(CreateExperiment(config=ExperimentConfig(
        name="bench", budget=n + 10, parallel=1, optimizer="random",
        space=_space()).to_json()))
    exp = resp.exp_id
    # warm one full cycle (jit, connection setup)
    s = client.suggest(exp, 1).suggestions[0]
    client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment, 0.0))
    samples = []
    for i in range(n):
        t0 = time.perf_counter()
        s = client.suggest(exp, 1).suggestions[0]
        client.observe(ObserveRequest(exp, s.suggestion_id, s.assignment,
                                      float(i)))
        samples.append((time.perf_counter() - t0) * 1e6)
    return samples


def run_service(n=50):
    """Service overhead: [(backend, us_per_suggest_observe_roundtrip)]."""
    rows = [("local", _roundtrips(LocalClient(tempfile.mkdtemp()), n))]
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        rows.append(("http", _roundtrips(HTTPClient(server.url), n)))
    finally:
        server.shutdown()
    return rows


def _reports(client, n):
    """n ctx.report round trips (metric append + shared-ASHA decision);
    returns per-report us samples."""
    exp = client.create_experiment(CreateExperiment(config=ExperimentConfig(
        name="bench-report", budget=10, parallel=1, optimizer="random",
        space=_space(),
        early_stop={"min_steps": 1, "eta": 3}).to_json())).exp_id
    client.report(ReportRequest(exp, "t0001", 1, 0.5))       # warm
    samples = []
    for i in range(n):
        t0 = time.perf_counter()
        client.report(ReportRequest(exp, "t0001", 2 + i, 0.5))
        samples.append((time.perf_counter() - t0) * 1e6)
    return samples


def run_report(n=200):
    """Trial-events overhead: [(backend, us_per_report_roundtrip)] — the
    per-step cost a training loop pays for service-side early stopping."""
    rows = [("report_local", _reports(LocalClient(tempfile.mkdtemp()), n))]
    server = serve_api(tempfile.mkdtemp()).start()
    try:
        rows.append(("report_http", _reports(HTTPClient(server.url), n)))
    finally:
        server.shutdown()
    return rows


def _contended(local_client, c, calls, think, seed_obs, prefetch,
               make_client=None):
    """Per-``suggest`` us samples across ``c`` clients, each in the
    scheduler's steady-state loop (suggest → observe → ``think`` seconds
    of trial turnaround).  GP optimizer: every observe costs a model fold
    and periodically a hyperparameter refit — with the pipeline off those
    serialize onto the suggest path; with it on the folds run in the
    pump and the refits on the shared fit executor."""
    cfg = ExperimentConfig(
        name="contend", budget=seed_obs + c * calls + 64, parallel=c,
        optimizer="gp", optimizer_options={"n_init": 8},
        prefetch=prefetch, space=_space())
    exp = local_client.create_experiment(
        CreateExperiment(config=cfg.to_json())).exp_id
    rng = np.random.default_rng(0)
    for i in range(seed_obs):       # active GP, realistic history
        s = local_client.suggest(exp, 1).suggestions[0]
        local_client.observe(ObserveRequest(
            exp, s.suggestion_id, s.assignment, float(rng.normal())))
    # steady state, not first-touch compiles: warm every shape bucket the
    # measured phase can grow into (the served path is always warm — the
    # pump prewarms at create; here we also cover the sync row and the
    # growth during measurement), then let the pump reach its fill level
    state = local_client._exps[exp]
    state.optimizer.prewarm(cfg.budget, batch=8)
    deadline = time.time() + 30
    while time.time() < deadline:
        st = local_client.status(exp)
        if not (st.pump and st.pump["alive"]) \
                or st.prefetched >= min(st.pump["depth"], 8):
            break
        time.sleep(0.05)
    lats, lock = [], threading.Lock()
    barrier = threading.Barrier(c)

    def worker(seed):
        client = make_client() if make_client else local_client
        client.status(exp)      # establish the keep-alive connection
        r = np.random.default_rng(seed)
        got = []
        barrier.wait()
        for _ in range(calls):
            t0 = time.perf_counter()
            batch = client.suggest(exp, 1)
            got.append(time.perf_counter() - t0)
            for s in batch.suggestions:
                client.observe(ObserveRequest(
                    exp, s.suggestion_id, s.assignment, float(r.normal())))
            time.sleep(think)
        with lock:
            lats.extend(got)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(c)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    local_client.stop(exp)
    return [float(v) for v in np.asarray(lats) * 1e6]


def run_contended(clients=(1, 8, 32), calls=8, think=0.1, seed_obs=40):
    """Suggest latency under contention: [(row, us_samples)] for the
    pipelined local + HTTP backends at each client count, plus the
    synchronous (``prefetch=0``) comparison row at 8 clients — the
    pre-pipeline behavior the ≥10x target in ISSUE 4 is measured
    against.  ``think`` models trial turnaround (a scheduler asks once
    per completion, not in a closed loop).  The gate value for these
    rows is the p50 over all per-call samples (``benchmarks/run.py``).

    The fixed client counts keep rows comparable across machines, but
    the largest (c32) oversubscribes a small host: 32 client threads on
    a 1-core container measure OS scheduler jitter, not the service
    (see ROADMAP.md's contended-row noise analysis).  The ``cauto``
    rows pin the count to min(4·cores, 32) — contended enough to
    exercise the pipeline, small enough to stay unimodal — and are
    what the tier-2 perf gate rides; the raw c32 rows stay tracked but
    ungated (scripts/bench_check.py UNGATED_ROWS)."""
    cauto = min(4 * (os.cpu_count() or 1), 32)
    rows = []
    for c, label in [(c, f"c{c}") for c in clients] + [(cauto, "cauto")]:
        local = LocalClient(tempfile.mkdtemp())
        rows.append((f"suggest_contended_local/{label}",
                     _contended(local, c, calls, think, seed_obs,
                                prefetch=None)))
        local.close()
    for c, label in [(c, f"c{c}") for c in clients] + [(cauto, "cauto")]:
        server = serve_api(tempfile.mkdtemp()).start()
        try:
            rows.append((f"suggest_contended_http/{label}",
                         _contended(server.backend, c, calls, think,
                                    seed_obs, prefetch=None,
                                    make_client=lambda: HTTPClient(
                                        server.url))))
        finally:
            server.shutdown()
    # reference row, not a served path: the synchronous (prefetch=0)
    # pre-pipeline behavior the >=10x ISSUE 4 target is quoted against
    local = LocalClient(tempfile.mkdtemp())
    rows.append(("suggest_contended_sync/c8",
                 _contended(local, 8, calls, think, seed_obs, prefetch=0)))
    local.close()
    return rows


def main():
    med = lambda s: float(np.percentile(s, 50))      # noqa: E731
    print("# ask() latency vs history size (p50 of per-call samples)")
    print("optimizer/history,us_per_call")
    for name, h, us in run():
        print(f"bench_suggest/{name}/h{h},{med(us):.0f}")
    print("# batched ask(8), per point")
    for name, h, us in run_batched():
        print(f"bench_suggest/{name}_batch8/h{h},{med(us):.0f}")
    print("# tell(1)+ask(1) cycle (includes the warm hyperparameter fit)")
    for name, h, us in run_cycle():
        print(f"bench_suggest/{name}_cycle/h{h},{med(us):.0f}")
    print("# suggest+observe round trip through the service API")
    print("backend,us_per_roundtrip")
    for backend, us in run_service():
        print(f"bench_service/{backend},{med(us):.0f}")
    print("# trial-progress report round trip (metrics + ASHA decision)")
    for backend, us in run_report():
        print(f"bench_service/{backend},{med(us):.0f}")
    print("# p50 suggest latency under client contention (GP, pipelined)")
    for row, us in run_contended():
        print(f"bench_service/{row},{med(us):.0f}")


if __name__ == "__main__":
    main()
