"""Suggestion-service latency: us per ask() at growing history sizes — the
hot path of the scheduler's fill loop."""
import time

import numpy as np

from repro.core.space import Param, Space
from repro.core.suggest import Observation, make_optimizer


def run(history_sizes=(10, 50, 150), names=("random", "sobol", "evolution",
                                            "pso", "gp")):
    space = Space([Param("a", "double", 0, 1),
                   Param("b", "double", 1e-4, 1, log=True),
                   Param("c", "int", 1, 64)])
    rng = np.random.default_rng(0)
    rows = []
    for name in names:
        for h in history_sizes:
            opt = make_optimizer(name, space, seed=0)
            obs = [Observation(a, float(rng.normal()))
                   for a in space.sample(rng, h)]
            opt.tell(obs)
            opt.ask(1)                      # warm caches / jit
            t0 = time.perf_counter()
            n = 10
            for _ in range(n):
                opt.ask(1)
            us = (time.perf_counter() - t0) / n * 1e6
            rows.append((name, h, us))
    return rows


def main():
    print("# ask() latency vs history size")
    print("optimizer/history,us_per_call")
    for name, h, us in run():
        print(f"bench_suggest/{name}/h{h},{us:.0f}")


if __name__ == "__main__":
    main()
