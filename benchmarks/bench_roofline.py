"""Roofline table from the dry-run artifacts (results/dryrun/*.json)."""
import json
import pathlib


def rows(mesh="16x16", root="results/dryrun"):
    out = []
    for p in sorted(pathlib.Path(root).glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            out.append((r["arch"], r["shape"], "SKIP", {}))
        elif r.get("ok"):
            out.append((r["arch"], r["shape"], r["roofline"]["dominant"],
                        r["roofline"]))
        else:
            out.append((r["arch"], r["shape"], "FAIL", {}))
    return out


def main():
    import pathlib
    has_final = pathlib.Path("results/dryrun_final").exists()
    final = {(a, s): rl for a, s, _, rl in rows(root="results/dryrun_final")}         if has_final else {}
    print("# roofline terms per (arch x shape), single-pod 16x16 "
          "(baseline; frac_opt = beyond-paper optimized build)")
    print("cell,us_per_call,derived")
    for arch, shape, dom, rl in rows():
        if not rl:
            print(f"bench_roofline/{arch}/{shape},0,{dom}")
            continue
        bound_us = rl["bound_s"] * 1e6
        opt = final.get((arch, shape)) or {}
        print(f"bench_roofline/{arch}/{shape},{bound_us:.0f},"
              f"dom={dom} frac={rl.get('roofline_fraction', 0):.4f} "
              f"frac_opt={opt.get('roofline_fraction', 0):.4f} "
              f"tc={rl['t_compute_s']:.3f} tm={rl['t_memory_s']:.3f} "
              f"tx={rl['t_collective_s']:.3f}")


if __name__ == "__main__":
    main()
