"""Paper §4 claim: parallel bandwidth cuts HPO wall-clock near-linearly
(300 evaluations, 15 simultaneous).  Simulated trial durations (lognormal,
like real model trainings) isolate orchestration efficiency from compute.
"""
import tempfile
import time

import numpy as np

from repro.core import (ExperimentConfig, Orchestrator, Param, Resources,
                        Space)


def run(budget=60, workers=(1, 5, 15), trial_mean_s=0.05):
    rows = []
    base = None
    for w in workers:
        orch = Orchestrator(tempfile.mkdtemp())
        rng = np.random.default_rng(0)

        def trial(a, ctx):
            dur = float(np.random.default_rng(
                int(a["x"] * 1e6)).lognormal(np.log(trial_mean_s), 0.3))
            time.sleep(dur)
            return -(a["x"] - 0.3) ** 2

        cfg = ExperimentConfig(name=f"par{w}", budget=budget, parallel=w,
                               optimizer="sobol",
                               space=Space([Param("x", "double", 0, 1)]))
        t0 = time.time()
        orch.run(cfg, trial_fn=trial)
        wall = time.time() - t0
        base = base or wall
        rows.append((w, wall, base / wall, base / wall / w))
    return rows


def main():
    print("# paper-section=4 parallel speedup (simulated trials)")
    print("workers,wall_s,speedup,efficiency")
    for w, wall, sp, eff in run():
        print(f"bench_parallel/w{w},{wall * 1e6 / 60:.0f},"
              f"speedup={sp:.2f}x eff={eff:.2f}")


if __name__ == "__main__":
    main()
