from repro.data.pipeline import (DataConfig, TokenPipeline, make_batch_fn,
                                 synthetic_corpus)

__all__ = ["DataConfig", "TokenPipeline", "make_batch_fn",
           "synthetic_corpus"]
