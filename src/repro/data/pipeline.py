"""Deterministic, shard-aware token pipeline.

Properties a 1000-node deployment needs and this implements:
* **Determinism**: batch t is a pure function of (seed, step, shard) — any
  worker can reconstruct any batch, so checkpoint-resume replays exactly and
  elastic re-sharding never duplicates or drops data.
* **Host sharding**: each data-parallel host pulls only its shard
  (``shard_id/num_shards``), indexing into a common stream — no coordinator.
* **Prefetch**: a background thread keeps ``prefetch`` batches ready so the
  accelerator never waits on host-side generation.

The corpus is a seeded Zipfian synthetic stream by default (offline
container); swapping in a real tokenized corpus only changes
``synthetic_corpus`` -> memory-mapped token file.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0
    zipf_a: float = 1.2
    prefetch: int = 2


def synthetic_corpus(cfg: DataConfig, step: int,
                     sample_ids: np.ndarray) -> np.ndarray:
    """Batch of token rows, pure function of (seed, sample_ids).

    Rows mix a Zipfian unigram stream with a deterministic repeated-motif
    structure so language models have actual signal to learn (loss drops
    below the unigram entropy), which the HPO examples rely on."""
    rows = []
    for sid in sample_ids:
        rng = np.random.default_rng((cfg.seed << 20) ^ int(sid))
        z = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1)
        toks = (z - 1) % cfg.vocab_size
        # motif: every row repeats a short pattern => learnable structure
        motif = rng.integers(0, cfg.vocab_size, size=8)
        pos = np.arange(cfg.seq_len + 1)
        use = (pos // 8) % 2 == 0
        toks = np.where(use, motif[pos % 8], toks)
        rows.append(toks)
    return np.stack(rows).astype(np.int32)


class TokenPipeline:
    def __init__(self, cfg: DataConfig,
                 corpus_fn: Callable = synthetic_corpus):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.corpus_fn = corpus_fn
        self.local_batch = cfg.global_batch // cfg.num_shards
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ core
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The shard-local batch for a global step (pure, replayable)."""
        base = step * self.cfg.global_batch
        ids = base + self.cfg.shard_id * self.local_batch + np.arange(
            self.local_batch)
        toks = self.corpus_fn(self.cfg, step, ids)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # ------------------------------------------------------------ prefetch
    def start_prefetch(self, from_step: int = 0) -> "TokenPipeline":
        self._q = queue.Queue(maxsize=self.cfg.prefetch)
        self._stop.clear()

        def worker():
            step = from_step
            while not self._stop.is_set():
                batch = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def next_prefetched(self):
        assert self._q is not None, "call start_prefetch first"
        return self._q.get()

    def stop_prefetch(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


def make_batch_fn(cfg: DataConfig) -> Callable[[int], Dict[str, np.ndarray]]:
    pipe = TokenPipeline(cfg)
    return pipe.batch_at
