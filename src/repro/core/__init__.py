# The paper's primary contribution — parallel hyperparameter-optimization
# infrastructure: spaces + suggestion service + cluster + scheduler +
# lifecycle + monitoring + population (vmap) execution.
from repro.core.cluster import Cluster, ClusterConfig, PoolConfig
from repro.core.experiment import ExperimentConfig, Resources, TrialSpec
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import Scheduler, TrialContext, TrialStopped
from repro.core.space import Param, Space
from repro.core.store import Store
from repro.core.suggest import ASHA, Observation, make_optimizer

__all__ = ["Cluster", "ClusterConfig", "PoolConfig", "ExperimentConfig",
           "Resources", "TrialSpec", "Orchestrator", "Scheduler",
           "TrialContext", "TrialStopped", "Param", "Space", "Store",
           "ASHA", "Observation", "make_optimizer"]
