"""Sobol quasi-random search — better space filling than iid random under
parallel asking (no two workers get clustered points)."""
from __future__ import annotations

from typing import List

from scipy.stats import qmc

from repro.core.space import Assignment, Space
from repro.core.suggest.base import Optimizer, register


@register("sobol")
class SobolSearch(Optimizer):
    def __init__(self, space: Space, seed: int = 0):
        super().__init__(space, seed)
        self._engine = qmc.Sobol(d=len(space), scramble=True, seed=seed)
        self._buf: List = []

    def ask(self, n: int = 1) -> List[Assignment]:
        while len(self._buf) < n:   # draw power-of-2 blocks (Sobol balance)
            self._buf.extend(list(self._engine.random(
                max(8, 1 << (n - 1).bit_length()))))
        u, self._buf = self._buf[:n], self._buf[n:]
        return [self.space.from_unit(row) for row in u]
