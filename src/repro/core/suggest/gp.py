"""Gaussian process regression in pure JAX (Matérn-5/2 ARD).

This is the numerical heart of the Bayesian optimizer — the in-repo stand-in
for SigOpt's hosted service.  Hyperparameters (per-dim lengthscales, signal
amplitude, noise) are fit by maximizing the exact log marginal likelihood
with Adam; posteriors use a jitter-stabilized Cholesky.

Hot-path design (the suggestion service calls this once per `ask` batch):

* **Bucketed static shapes** — training sets are padded to power-of-two
  buckets with a 0/1 mask, so every jitted function sees one shape per
  bucket and XLA compiles once per bucket instead of once per observation
  count.  Padded slots carry an identity block in the covariance, which
  makes the masked Cholesky exactly the real Cholesky plus identity rows.
* **Rank-1 appends** — ``append_point`` / ``append_lie`` grow the posterior
  into a free padded slot with a bordered-Cholesky update: O(n²) per point
  instead of a fresh O(steps·n³) hyperparameter fit.  Constant-liar
  batching in ``BayesOpt`` rides on this.
* **Batched q-EI selection** — ``select_batch`` picks a whole batch of
  suggestions in one jitted scan (EI argmax → fold lie → repeat), so the
  per-point Python/dispatch overhead vanishes.
* **Warm starts** — ``fit_gp(..., params0=...)`` resumes Adam from the
  previous optimum so converged posteriors need far fewer steps.
* **Sparse speculative posterior** — ``sparse_posterior`` builds an exact
  GP over a subset-of-data design of at most ``SPARSE_MAX`` inducing
  points (incumbent + recency window + even coverage of the older
  history), so conditioning cost is O(m³) regardless of history size.
  The suggestion service uses it *only* to refill the speculative
  prefetch queue when the exact path is saturated (ISSUE 5) — exact
  posteriors still serve synchronous asks and coalesced misses, and
  queue entries are staleness-bounded, which contains the approximation
  error.  It returns an ordinary ``GPPosterior`` in an ordinary
  power-of-two bucket, so every jitted kernel (EI, rank-1 appends, the
  q-EI scan) and the ``prewarm_bucket`` compile cache apply unchanged.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _kops

MIN_BUCKET = 16

#: Cap on lanes per batched fit dispatch (``batched_fit``): beyond this
#: the O(k·b³) Adam loop stops amortizing dispatch overhead and only
#: grows compile variants; callers split larger sets into chunks.
FIT_LANES_MAX = 32

#: Scan-length pad of the *batched* q-EI select (``batched_select``):
#: every batched refill ask runs a ``SELECT_PAD``-step scan with its live
#: pick count traced, so lanes wanting different batch sizes still share
#: one compile per (bucket, SELECT_PAD, lane-pad) — and the service's
#: refill chunk (``pipeline.ASK_CHUNK``) is sized to never exceed it.
#: The solo ``select_batch`` path keeps its natural per-k pads.
SELECT_PAD = 8

#: Cap on the subset-of-data design of the sparse speculative posterior.
#: 64 keeps the sparse Cholesky inside the two smallest non-trivial shape
#: buckets (64/128 once lies and picks are folded in), which ``prewarm``
#: always compiles first — a sparse refill never waits on XLA.
SPARSE_MAX = 64


def bucket_size(n: int, minimum: int = MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= n (>= minimum)."""
    b = minimum
    while b < n:
        b *= 2
    return b


def _dtype():
    return jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32


class GPParams(NamedTuple):
    log_ls: jnp.ndarray       # (d,) log lengthscales
    log_amp: jnp.ndarray      # () log signal stddev
    log_noise: jnp.ndarray    # () log noise stddev


class GPPosterior(NamedTuple):
    params: GPParams
    x: jnp.ndarray            # (b,d) training inputs, padded to bucket
    mask: jnp.ndarray         # (b,) 1.0 for real rows, 0.0 for padding
    y: jnp.ndarray            # (b,) normalized targets (0 at padding)
    chol: jnp.ndarray         # (b,b) cholesky of masked K + noise
    alpha: jnp.ndarray        # (b,) K^{-1} y
    y_mean: jnp.ndarray       # ()
    y_std: jnp.ndarray        # ()

    @property
    def capacity(self) -> int:
        return int(self.x.shape[0])


def _sqdist(a: jnp.ndarray, b: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    a = a / ls
    b = b / ls
    return jnp.maximum(
        jnp.sum(a * a, -1)[:, None] - 2 * a @ b.T + jnp.sum(b * b, -1)[None],
        0.0)


def matern52(a, b, params: GPParams) -> jnp.ndarray:
    ls = jnp.exp(params.log_ls)
    amp2 = jnp.exp(2 * params.log_amp)
    r = jnp.sqrt(_sqdist(a, b, ls) + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    return amp2 * (1 + s5r + 5.0 / 3.0 * r * r) * jnp.exp(-s5r)


def _noise2(params: GPParams) -> jnp.ndarray:
    return jnp.exp(2 * params.log_noise) + 1e-5


def _masked_cov(params: GPParams, x: jnp.ndarray,
                mask: jnp.ndarray) -> jnp.ndarray:
    """Covariance with padded rows/cols replaced by an identity block, so
    cholesky(masked K) == blockdiag(cholesky(real K), I)."""
    b = x.shape[0]
    k = matern52(x, x, params) + _noise2(params) * jnp.eye(b)
    mm = mask[:, None] * mask[None, :]
    return k * mm + jnp.diag(1.0 - mask)


@jax.jit
def neg_mll(params: GPParams, x: jnp.ndarray, y: jnp.ndarray,
            mask: jnp.ndarray) -> jnp.ndarray:
    """Exact negative log marginal likelihood over the masked rows only:
    identity padding contributes log(1)=0 to the determinant and 0 to the
    quadratic form, so the value is independent of the bucket size."""
    k = _masked_cov(params, x, mask)
    chol = jnp.linalg.cholesky(k)
    ym = y * mask
    alpha = jax.scipy.linalg.cho_solve((chol, True), ym)
    return (0.5 * ym @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * jnp.sum(mask) * jnp.log(2 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("steps",))
def _fit(params0: GPParams, x, y, mask, steps: int = 150, lr: float = 0.05):
    """Adam on the negative MLL."""
    def adam_step(carry, _):
        p, m, v, t = carry
        g = jax.grad(neg_mll)(p, x, y, mask)
        t = t + 1
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, m, g)
        v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg, v, g)
        mh = jax.tree.map(lambda mm: mm / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + 1e-8),
                         p, mh, vh)
        # clamp to sane ranges to keep the Cholesky healthy; reject any
        # step that went NaN (singular K during the line search)
        p = GPParams(jnp.clip(p.log_ls, -3.0, 1.5),
                     jnp.clip(p.log_amp, -3.0, 2.0),
                     jnp.clip(p.log_noise, -5.0, 1.0))
        ok = jnp.all(jnp.stack([jnp.all(jnp.isfinite(x))
                                for x in jax.tree.leaves(p)]))
        prev = carry[0]
        p = jax.tree.map(lambda new, old: jnp.where(ok, new, old), p, prev)
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params0)
    (p, _, _, _), _ = jax.lax.scan(
        adam_step, (params0, zeros, zeros, jnp.zeros((), jnp.int32)),
        None, length=steps)
    return p


def lane_pad(k: int) -> int:
    """Smallest power of two >= k — the lane-count pad of ``batched_fit``
    and ``batched_select`` (one ``_fit_lanes`` compile per
    (bucket, max-steps, lane-pad) triple, one ``_select_lanes`` compile
    per (bucket, k-pad, lane-pad) triple)."""
    return 1 << max(0, int(k) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _fit_lanes(params0: GPParams, x, y, mask, steps, max_steps: int = 150,
               lr: float = 0.05):
    """Batched ``_fit``: every GPParams leaf and data array carries a
    leading lane axis (k experiments), and one Adam loop advances all
    lanes together — the per-lane gradients come from one batched
    dispatch (``ops.gp_fit_grads``: the fused Pallas neg-MLL's analytic
    custom_vjp on TPU, the GEMM-rich analytic adjoint from kernels/ref
    here on CPU — the latter is why a lane costs less than a serial
    autodiff fit even on one core).  Lanes are independent: the adjoint
    is computed per lane, and the NaN-reject check is per-lane, so one
    ill-conditioned experiment can't stall its batch peers.
    All-zero-mask lanes (the lane padding) see an identity covariance —
    zero gradient, parameters inert.

    ``steps`` is a traced (k,) int32 of per-lane step budgets and
    ``max_steps`` the static scan length (>= every entry): the loop runs
    ``max_steps`` iterations with a per-lane freeze mask that discards a
    lane's parameter update once its own budget is spent.  Lanes on
    different rungs of the adaptive warm-step ladder therefore share one
    dispatch, and because every live lane sees the identical global Adam
    step index ``t``, a lane frozen at ``steps[i]`` holds exactly the
    parameters a solo ``_fit_lanes`` run of length ``steps[i]`` would
    produce — bit-identical, not merely close."""
    def adam_step(carry, _):
        p, m, v, t = carry
        g = GPParams(*_kops.gp_fit_grads(p.log_ls, p.log_amp,
                                         p.log_noise, x, y, mask))
        t = t + 1
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, m, g)
        v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg, v, g)
        mh = jax.tree.map(lambda mm: mm / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + 1e-8),
                         p, mh, vh)
        p = GPParams(jnp.clip(p.log_ls, -3.0, 1.5),
                     jnp.clip(p.log_amp, -3.0, 2.0),
                     jnp.clip(p.log_noise, -5.0, 1.0))
        ok = (jnp.all(jnp.isfinite(p.log_ls), axis=-1)
              & jnp.isfinite(p.log_amp) & jnp.isfinite(p.log_noise))  # (k,)
        keep = ok & (t <= steps)                 # freeze finished lanes
        prev = carry[0]
        p = GPParams(jnp.where(keep[:, None], p.log_ls, prev.log_ls),
                     jnp.where(keep, p.log_amp, prev.log_amp),
                     jnp.where(keep, p.log_noise, prev.log_noise))
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params0)
    (p, _, _, _), _ = jax.lax.scan(
        adam_step, (params0, zeros, zeros, jnp.zeros((), jnp.int32)),
        None, length=max_steps)
    return p


def batched_fit(items, steps=150, bucket: Optional[int] = None) -> list:
    """Fit k experiments' GP hyperparameters in ONE vmap'd dispatch.

    ``items`` is a sequence of ``(x, y, params0)`` triples — x (n,d) in
    the unit cube, y raw objective, params0 a warm start or None — all
    sharing one shape ``bucket`` (default: smallest bucket fitting the
    largest history).  Each lane is normalized and padded exactly as
    ``fit_gp`` would, stacked along a leading lane axis, and the lane
    count is padded to the next power of two with inert all-zero-mask
    lanes, so XLA compiles once per (bucket, max-steps, lane-pad) triple.

    ``steps`` is an int (every lane) or a per-lane sequence: lanes on
    different adaptive-ladder step counts run inside one masked loop of
    ``max(steps)`` iterations (see ``_fit_lanes``) — each lane's result
    is bit-identical to a solo fit at its own step count.  Returns a
    list of k fitted ``GPParams`` (install with ``make_posterior`` /
    the optimizer's recondition, as usual)."""
    if not items:
        return []
    if len(items) > FIT_LANES_MAX:
        raise ValueError(f"{len(items)} lanes > FIT_LANES_MAX "
                         f"({FIT_LANES_MAX}); split the batch")
    dtype = _dtype()
    b = bucket if bucket is not None else bucket_size(
        max(np.asarray(x).shape[0] for x, _, _ in items))
    b = int(b)
    d = np.asarray(items[0][0]).shape[1]
    k = len(items)
    kp = lane_pad(k)
    steps_list = ([int(steps)] * k if isinstance(steps, (int, np.integer))
                  else [int(s) for s in steps])
    if len(steps_list) != k:
        raise ValueError(f"{len(steps_list)} step counts for {k} lanes")
    # one host-side buffer per array and ONE device put each — k small
    # transfers per lane would cost more than the fit at warm step counts
    xs = np.zeros((kp, b, d), np.float64)
    ys = np.zeros((kp, b), np.float64)
    ms = np.zeros((kp, b), np.float64)
    lls = np.full((kp, d), -0.7, np.float64)
    las = np.zeros((kp,), np.float64)
    lns = np.full((kp,), -2.0, np.float64)
    st = np.zeros((kp,), np.int32)
    st[:k] = steps_list
    for i, (x, y, params0) in enumerate(items):
        x = np.asarray(x, np.float64)
        y_raw = np.asarray(y, np.float64)
        n = x.shape[0]
        if b < n:
            raise ValueError(f"bucket {b} smaller than training set {n}")
        mean = np.mean(y_raw)
        std = max(float(np.std(y_raw)), 1e-6)
        xs[i, :n] = x
        ys[i, :n] = (y_raw - mean) / std
        ms[i, :n] = 1.0
        if params0 is not None:
            lls[i] = np.asarray(params0.log_ls)
            las[i] = np.asarray(params0.log_amp)
            lns[i] = np.asarray(params0.log_noise)
    # lanes k..kp-1 stay all-zero-mask (inert) with default params
    p0 = GPParams(jnp.asarray(lls, dtype), jnp.asarray(las, dtype),
                  jnp.asarray(lns, dtype))
    p = _fit_lanes(p0, jnp.asarray(xs, dtype), jnp.asarray(ys, dtype),
                   jnp.asarray(ms, dtype), jnp.asarray(st),
                   max_steps=max(steps_list))
    jax.block_until_ready(p.log_ls)
    return [GPParams(p.log_ls[i], p.log_amp[i], p.log_noise[i])
            for i in range(k)]


@jax.jit
def _posterior(params: GPParams, x, y, mask, y_mean, y_std) -> GPPosterior:
    k = _masked_cov(params, x, mask)
    chol = jnp.linalg.cholesky(k)
    ym = y * mask
    alpha = jax.scipy.linalg.cho_solve((chol, True), ym)
    return GPPosterior(params, x, mask, ym, chol, alpha, y_mean, y_std)


def _pad(x: np.ndarray, y: np.ndarray, bucket: int, dtype):
    # pad on the host: device-side .at[:n].set would compile a fresh
    # scatter for every distinct n, defeating the bucketing
    n, d = x.shape
    xp = np.zeros((bucket, d), np.float64)
    xp[:n] = x
    yp = np.zeros((bucket,), np.float64)
    yp[:n] = y
    mask = np.zeros((bucket,), np.float64)
    mask[:n] = 1.0
    return (jnp.asarray(xp, dtype), jnp.asarray(yp, dtype),
            jnp.asarray(mask, dtype))


def fit_gp(x: np.ndarray, y: np.ndarray, steps: int = 150,
           params0: Optional[GPParams] = None,
           bucket: Optional[int] = None) -> GPPosterior:
    """x in unit cube (n,d); y raw objective (normalized internally).

    ``bucket`` pads the training set to a static shape (default: smallest
    power-of-two bucket); ``params0`` warm-starts Adam from a previous fit.
    """
    dtype = _dtype()
    x = np.asarray(x, np.float64)
    y_raw = np.asarray(y, np.float64)
    n, d = x.shape
    b = bucket_size(n) if bucket is None else int(bucket)
    if b < n:
        raise ValueError(f"bucket {b} smaller than training set {n}")
    # normalize on the host: device ops on the unpadded (n,) array would
    # compile per history size
    mean = float(np.mean(y_raw))
    std = max(float(np.std(y_raw)), 1e-6)
    y_mean = jnp.asarray(mean, dtype)
    y_std = jnp.asarray(std, dtype)
    xp, ynp, mask = _pad(x, (y_raw - mean) / std, b, dtype)
    if params0 is None:
        p0 = GPParams(jnp.zeros(d, dtype) - 0.7, jnp.zeros((), dtype),
                      jnp.zeros((), dtype) - 2.0)
    else:
        p0 = jax.tree.map(lambda a: jnp.asarray(a, dtype), params0)
    p = _fit(p0, xp, ynp, mask, steps=steps)
    return _posterior(p, xp, ynp, mask, y_mean, y_std)


def make_posterior(params: GPParams, x: np.ndarray, y: np.ndarray,
                   y_mean=None, y_std=None,
                   bucket: Optional[int] = None) -> GPPosterior:
    """Exact posterior for *given* hyperparameters (no fitting) — the
    reference implementation the rank-1 update path is tested against."""
    dtype = _dtype()
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    b = bucket_size(x.shape[0]) if bucket is None else int(bucket)
    mean = float(np.mean(y) if y_mean is None else y_mean)
    std = max(float(np.std(y) if y_std is None else y_std), 1e-6)
    xp, ynp, mask = _pad(x, (y - mean) / std, b, dtype)
    return _posterior(jax.tree.map(lambda a: jnp.asarray(a, dtype), params),
                      xp, ynp, mask, jnp.asarray(mean, dtype),
                      jnp.asarray(std, dtype))


# ------------------------------------------------------- sparse posterior
def sparse_subset(n: int, best_idx: int, m: int = SPARSE_MAX) -> np.ndarray:
    """Indices of the subset-of-data design over an ``n``-point history:
    the incumbent (``best_idx``), the most recent ``m // 2`` points (the
    region speculation is actively exploring — and the rows the staleness
    bound judges freshness against), and an even stride over the older
    remainder for global coverage.  Deterministic in (n, best_idx, m) so
    repeated reconditions reuse the same design and tests can assert on
    it.  Returns sorted unique indices, ``len <= m``."""
    n = int(n)
    m = max(1, int(m))
    if n <= m:
        return np.arange(n)
    recent = np.arange(n - m // 2, n)
    rest = m - len(recent) - 1                    # slots for old coverage
    old = np.linspace(0, n - m // 2 - 1, num=max(rest, 0)).astype(int) \
        if rest > 0 else np.empty(0, int)
    return np.unique(np.concatenate([[int(best_idx)], old, recent]))


def sparse_posterior(params: GPParams, x: np.ndarray, y: np.ndarray,
                     m: int = SPARSE_MAX, extra: int = 0
                     ) -> Tuple[GPPosterior, np.ndarray]:
    """Sparse speculative posterior: an *exact* GP conditioned on the
    ``sparse_subset`` design only, at the given (already-fit)
    hyperparameters — conditioning is one O(m³) Cholesky independent of
    history size.  ``extra`` reserves padded slots for constant-liar
    folds on top of the subset (the bucket is sized to absorb them), so
    ``append_lie``/``select_batch`` work on the result unchanged.
    Normalization uses the *full* history's mean/std: predicted means and
    the EI ``best`` threshold stay in the same raw units as the exact
    posterior.  Returns (posterior, subset indices)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    idx = sparse_subset(len(x), int(np.argmax(y)), m)
    bucket = bucket_size(len(idx) + max(0, int(extra)))
    mean = float(np.mean(y))
    std = max(float(np.std(y)), 1e-6)
    post = make_posterior(params, x[idx], y[idx], y_mean=mean, y_std=std,
                          bucket=bucket)
    return post, idx


# ---------------------------------------------------------------- prewarm
def prewarm_bucket(d: int, bucket: int, fit_steps=(), k_pads=(),
                   n_cand: int = 64, fit_lanes=(), select_lanes=()) -> None:
    """Compile every jitted kernel on the ask path for one bucket shape,
    using throwaway data: the hyperparameter fit (one ``_fit`` variant per
    entry in ``fit_steps``), the exact posterior, the rank-1 appends, and
    the q-EI scan (one variant per ``k_pads`` entry, at the real candidate
    pool size ``n_cand``).  XLA caches compilations per shape signature,
    so calling this off the request path moves the first-touch compile
    cost (~0.7 s per bucket on the dev container) out of ``ask`` — the
    dominant term in the cold `gp/h10` and bucket-crossing `gp_batch8`
    latencies.  Idempotent: re-running against warm caches costs only the
    (small) dummy-data compute.

    ``fit_lanes`` is the k-pad ladder of the batched executor path
    (ISSUE 8): for each lane count the ``_fit_lanes`` variant is
    compiled at every ``fit_steps`` entry, so a fleet's first batched
    refit dispatch doesn't pay its (bucket, steps, lane-pad) compile
    under load.  Off by default — batched dispatches already run off
    the request path, so lazy first-touch compiles only delay one
    install.

    ``select_lanes`` is the analogous lane-pad ladder of the batched
    *ask* path (ISSUE 10): for each lane count the ``_select_lanes``
    variant is compiled at the fixed ``SELECT_PAD`` scan length and the
    real pool size ``n_cand``, so a shard's first co-batched refill
    dispatch never XLA-compiles mid-run."""
    x = np.zeros((2, d), np.float64)
    x[1] = 0.5
    y = np.array([0.0, 1.0], np.float64)
    post = None
    for s in sorted({int(s) for s in fit_steps}):
        post = fit_gp(x, y, steps=s, bucket=bucket)
        for lanes in sorted({lane_pad(int(kp)) for kp in fit_lanes}):
            batched_fit([(x, y, None)] * lanes, steps=s, bucket=bucket)
    if post is None:
        post = make_posterior(
            GPParams(jnp.zeros(d, _dtype()), jnp.zeros(()), jnp.zeros(())),
            x, y, bucket=bucket)
    # match the real call signatures exactly (host numpy float32 inputs)
    append_point(post, np.asarray(x[0], np.float32), np.float32(0.5))
    append_lie(post, np.asarray(x[0], np.float32))
    cand = np.zeros((n_cand, d), np.float32)
    for kp in sorted({int(k) for k in k_pads}):
        if kp + 2 <= bucket:    # the scan needs kp free padded slots
            select_batch(post, cand, np.float32(1.0), kp)
    if SELECT_PAD + 2 <= bucket:
        for lanes in sorted({lane_pad(int(s)) for s in select_lanes}):
            batched_select([(post, cand, np.float32(1.0), 1)] * lanes)


# ---------------------------------------------------------------- queries
@jax.jit
def predict(post: GPPosterior, xq: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean/stddev at query points (m,d) — in raw y units."""
    kq = matern52(xq, post.x, post.params) * post.mask[None, :]   # (m,b)
    mu = kq @ post.alpha
    v = jax.scipy.linalg.solve_triangular(post.chol, kq.T, lower=True)
    amp2 = jnp.exp(2 * post.params.log_amp)
    var = jnp.maximum(amp2 - jnp.sum(v * v, axis=0), 1e-12)
    return (mu * post.y_std + post.y_mean,
            jnp.sqrt(var) * post.y_std)


@jax.jit
def expected_improvement(post: GPPosterior, xq: jnp.ndarray,
                         best: jnp.ndarray, xi: float = 0.01) -> jnp.ndarray:
    mu, sd = predict(post, xq)
    z = (mu - best - xi) / sd
    ncdf = 0.5 * (1 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    npdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2 * jnp.pi)
    return (mu - best - xi) * ncdf + sd * npdf


# ---------------------------------------------------------- rank-1 growth
def _append_norm(post: GPPosterior, xn: jnp.ndarray,
                 yn: jnp.ndarray) -> GPPosterior:
    """Grow the posterior into the first free padded slot: bordered
    Cholesky (new row [l12, l22]) + two triangular solves for alpha.
    O(b²); hyperparameters and y-normalization are frozen.  Real rows
    occupy a prefix, so the new point *is* the last real row and the
    identity rows below it stay a valid Cholesky of the masked cov."""
    idx = jnp.sum(post.mask).astype(jnp.int32)
    kvec = (matern52(xn[None], post.x, post.params)[0] * post.mask)
    l12 = jax.scipy.linalg.solve_triangular(post.chol, kvec, lower=True)
    kss = jnp.exp(2 * post.params.log_amp) + _noise2(post.params)
    l22 = jnp.sqrt(jnp.maximum(kss - l12 @ l12, 1e-10))
    chol = post.chol.at[idx, :].set(l12.at[idx].set(l22))
    x = post.x.at[idx].set(xn)
    mask = post.mask.at[idx].set(1.0)
    y = post.y.at[idx].set(yn)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return GPPosterior(post.params, x, mask, y, chol, alpha,
                       post.y_mean, post.y_std)


@jax.jit
def append_point(post: GPPosterior, xn: jnp.ndarray,
                 y_raw: jnp.ndarray) -> GPPosterior:
    """Rank-1 fold of a real observation (raw y units)."""
    return _append_norm(post, xn, (y_raw - post.y_mean) / post.y_std)


@jax.jit
def append_lie(post: GPPosterior, xn: jnp.ndarray) -> GPPosterior:
    """Constant liar: pin a pending suggestion at its posterior mean."""
    kvec = matern52(xn[None], post.x, post.params)[0] * post.mask
    return _append_norm(post, xn, kvec @ post.alpha)


@functools.partial(jax.jit, static_argnames=("k_pad",))
def _select_scan(post: GPPosterior, cand: jnp.ndarray, best: jnp.ndarray,
                 k: jnp.ndarray, k_pad: int):
    """q-EI by sequential constant-liar greedy, fully inside one jitted
    scan: argmax EI over the candidate pool, fold the pick in as a lie,
    repeat.  The scan length is padded to ``k_pad`` (a power of two) with
    the live count ``k`` traced, so varying batch sizes share one compile
    per bucket; steps past ``k`` are computed then reverted wholesale."""
    m = cand.shape[0]

    def step(carry, i):
        p, taken = carry
        ei = expected_improvement(p, cand, best)
        ei = jnp.where(taken, -jnp.inf, ei)
        j = jnp.argmax(ei)
        p2 = append_lie(p, cand[j])
        live = i < k
        p = jax.tree.map(lambda new, old: jnp.where(live, new, old), p2, p)
        taken = jnp.where(live, taken.at[j].set(True), taken)
        return (p, taken), j

    (post, _), picks = jax.lax.scan(
        step, (post, jnp.zeros((m,), bool)), jnp.arange(k_pad))
    return picks, post


def select_batch(post: GPPosterior, cand: jnp.ndarray, best,
                 k: int) -> Tuple[jnp.ndarray, GPPosterior]:
    """Pick k batch points by greedy q-EI with constant-liar updates in
    one jitted pass.  Returns (picked candidate indices (k,), posterior
    with the k lies folded in).  The posterior must have >= k free slots;
    compiles once per (bucket, next-power-of-two(k))."""
    k = int(k)
    k_pad = 1 << max(0, k - 1).bit_length()
    picks, post = _select_scan(post, jnp.asarray(cand),
                               jnp.asarray(best, post.y_mean.dtype),
                               jnp.asarray(k, jnp.int32), k_pad)
    return picks[:k], post


# ----------------------------------------------------- batched q-EI select
@functools.partial(jax.jit, static_argnames=("k_pad",))
def _select_lanes(post: GPPosterior, cand: jnp.ndarray, best: jnp.ndarray,
                  k: jnp.ndarray, k_pad: int):
    """Lane-batched ``_select_scan``: every posterior leaf, the candidate
    pool (kl,m,d), the EI threshold ``best`` (kl,) and the live pick
    count ``k`` (kl,) carry a leading lane axis, and one greedy
    constant-liar scan advances all lanes together.

    Unlike the serial scan — which recomputes the full cross-covariance
    ``kq = cov(cand, X)`` and whitened solve ``v = L⁻¹kqᵀ`` (O(b²m))
    every step — the batched scan pays that factorization ONCE per
    dispatch and extends it incrementally: a lie append adds one bordered
    Cholesky row, so only one new column of ``kq`` (O(md)), one forward-
    substitution row of ``v`` (O(bm)) and a rank-1 update of the
    predictive-variance partials change per step.  The step-0 EI is
    algebraically the same quantity ``ops.gp_ei`` computes (mirrored
    here so the factors stay live in the scan carry); every serial step
    after it drops from O(b²m) to O(bm), which is what makes the batched
    plane cheaper per ask than the serial path even on a single-core CPU
    host where vmap buys no parallelism (see benchmarks/bench_ask.py).

    Lanes are independent: a lane whose own ``k`` is spent (and the
    all-zero-mask lane padding, where k == 0) keeps computing but has
    its posterior and taken-mask updates reverted — the carried
    ``kq/v/ss`` factors are deliberately left hot, since a dead lane's
    later picks and factors are discarded by the caller and never feed
    another lane.  Mixed batch sizes share one compile per (bucket,
    k_pad, lane-pad) triple."""
    kl, m = cand.shape[0], cand.shape[1]
    lanes = jnp.arange(kl)

    def factorize(p, c):
        kq = matern52(c, p.x, p.params) * p.mask[None, :]        # (m,b)
        v = jax.scipy.linalg.solve_triangular(p.chol, kq.T,
                                              lower=True)        # (b,m)
        return kq, v
    kq, v = jax.vmap(factorize)(post, cand)
    ss = jnp.sum(v * v, axis=1)                                  # (kl,m)

    def lane_step(p, kq, v, ss, taken, c, b_inc, k1, i):
        amp2 = jnp.exp(2 * p.params.log_amp)
        mu_n = kq @ p.alpha                                      # (m,)
        var = jnp.maximum(amp2 - ss, 1e-12)
        mu = mu_n * p.y_std + p.y_mean
        sd = jnp.sqrt(var) * p.y_std
        z = (mu - b_inc - 0.01) / sd
        ncdf = 0.5 * (1 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
        npdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2 * jnp.pi)
        ei = (mu - b_inc - 0.01) * ncdf + sd * npdf
        ei = jnp.where(taken, -jnp.inf, ei)
        j = jnp.argmax(ei)
        xn = c[j]
        # bordered-Cholesky append (mirrors _append_norm), reusing the
        # carried factors: l12 = L⁻¹ cov(xn, X) is column j of v and
        # l12·l12 is ss[j] — both already paid for
        idx = jnp.sum(p.mask).astype(jnp.int32)
        l12 = v[:, j]
        kss = amp2 + _noise2(p.params)
        l22 = jnp.sqrt(jnp.maximum(kss - ss[j], 1e-10))
        chol = p.chol.at[idx, :].set(l12.at[idx].set(l22))
        x = p.x.at[idx].set(xn)
        mask = p.mask.at[idx].set(1.0)
        y = p.y.at[idx].set(mu_n[j])                 # constant liar
        alpha = jax.scipy.linalg.cho_solve((chol, True), y)
        p2 = GPPosterior(p.params, x, mask, y, chol, alpha,
                         p.y_mean, p.y_std)
        # extend the factors by the new posterior row: one kernel column,
        # one forward-substitution row, one variance partial
        kq_col = matern52(c, xn[None], p.params)[:, 0]           # (m,)
        kq2 = kq.at[:, idx].set(kq_col)
        v_row = (kq_col - l12 @ v) / l22                         # (m,)
        v2 = v.at[idx, :].set(v_row)
        ss2 = ss + v_row * v_row
        live = i < k1
        p = jax.tree.map(lambda new, old: jnp.where(live, new, old), p2, p)
        taken = jnp.where(live, taken.at[j].set(True), taken)
        return p, kq2, v2, ss2, taken, j

    def step(carry, i):
        p, kq, v, ss, taken = carry
        p, kq, v, ss, taken, j = jax.vmap(
            lane_step, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None))(
            p, kq, v, ss, taken, cand, best, k, i)
        return (p, kq, v, ss, taken), j

    (post, _, _, _, _), picks = jax.lax.scan(
        step, (post, kq, v, ss, jnp.zeros((kl, m), bool)),
        jnp.arange(k_pad))
    return picks.T, post                                     # (kl,k_pad)


def _inert_posterior(b: int, d: int, dtype) -> GPPosterior:
    """Lane padding for ``batched_select``: an empty posterior whose
    masked covariance is the identity — chol = I, alpha = 0, so EI and
    the bordered-Cholesky append stay finite — and whose k == 0 means
    every scan step is reverted anyway."""
    return GPPosterior(
        GPParams(jnp.zeros((d,), dtype), jnp.zeros((), dtype),
                 jnp.zeros((), dtype)),
        jnp.zeros((b, d), dtype), jnp.zeros((b,), dtype),
        jnp.zeros((b,), dtype), jnp.eye(b, dtype=dtype),
        jnp.zeros((b,), dtype), jnp.zeros((), dtype),
        jnp.ones((), dtype))


def batched_select(items, k_pad: int = SELECT_PAD) -> list:
    """Run k experiments' q-EI batch selections in ONE vmap'd dispatch.

    ``items`` is a sequence of ``(post, cand, best, k)`` tuples — post a
    ``GPPosterior``, cand (m,d) candidate pool, best the raw-units EI
    incumbent, k <= ``k_pad`` the live pick count — all sharing one
    posterior bucket and one pool shape.  Posteriors are stacked along a
    leading lane axis, the lane count is padded to the next power of two
    with inert lanes, and the scan length is the fixed ``k_pad`` (default
    ``SELECT_PAD``) with per-lane k traced, so XLA compiles once per
    (bucket, k_pad, lane-pad) triple regardless of each lane's batch
    size.  Returns a list of k ``(picks, post)`` pairs exactly as
    ``select_batch`` would produce — picks (k_i,) candidate indices,
    post the lane's posterior with its k_i lies folded in."""
    if not items:
        return []
    dtype = _dtype()
    kl = len(items)
    klp = lane_pad(kl)
    b = items[0][0].capacity
    d = int(items[0][0].x.shape[1])
    m = int(np.asarray(items[0][1]).shape[0])
    posts = []
    cands = np.zeros((klp, m, d), np.float32)
    bests = np.zeros((klp,), np.float64)
    ks = np.zeros((klp,), np.int32)
    for i, (post, cand, best, k) in enumerate(items):
        if post.capacity != b:
            raise ValueError(f"lane {i}: bucket {post.capacity} != {b}")
        cand = np.asarray(cand, np.float32)
        if cand.shape != (m, d):
            raise ValueError(f"lane {i}: pool {cand.shape} != {(m, d)}")
        if not 0 < int(k) <= k_pad:
            raise ValueError(f"lane {i}: k={k} outside (0, {k_pad}]")
        posts.append(post)
        cands[i] = cand
        bests[i] = float(best)
        ks[i] = int(k)
    posts.extend(_inert_posterior(b, d, dtype) for _ in range(klp - kl))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *posts)
    picks, posts_out = _select_lanes(
        stacked, jnp.asarray(cands, dtype), jnp.asarray(bests, dtype),
        jnp.asarray(ks), int(k_pad))
    jax.block_until_ready(picks)
    return [(picks[i, :int(ks[i])],
             jax.tree.map(lambda a, i=i: a[i], posts_out))
            for i in range(kl)]
