"""Gaussian process regression in pure JAX (Matérn-5/2 ARD).

This is the numerical heart of the Bayesian optimizer — the in-repo stand-in
for SigOpt's hosted service.  Hyperparameters (per-dim lengthscales, signal
amplitude, noise) are fit by maximizing the exact log marginal likelihood
with Adam; posteriors use a jitter-stabilized Cholesky.  Everything is jit
compiled and sized for HPO workloads (n <= a few hundred observations).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class GPParams(NamedTuple):
    log_ls: jnp.ndarray       # (d,) log lengthscales
    log_amp: jnp.ndarray      # () log signal stddev
    log_noise: jnp.ndarray    # () log noise stddev


class GPPosterior(NamedTuple):
    params: GPParams
    x: jnp.ndarray            # (n,d) training inputs (unit cube)
    chol: jnp.ndarray         # (n,n) cholesky of K + noise
    alpha: jnp.ndarray        # (n,) K^{-1} (y - mean)
    y_mean: jnp.ndarray       # ()
    y_std: jnp.ndarray        # ()


def _sqdist(a: jnp.ndarray, b: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    a = a / ls
    b = b / ls
    return jnp.maximum(
        jnp.sum(a * a, -1)[:, None] - 2 * a @ b.T + jnp.sum(b * b, -1)[None],
        0.0)


def matern52(a, b, params: GPParams) -> jnp.ndarray:
    ls = jnp.exp(params.log_ls)
    amp2 = jnp.exp(2 * params.log_amp)
    r = jnp.sqrt(_sqdist(a, b, ls) + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    return amp2 * (1 + s5r + 5.0 / 3.0 * r * r) * jnp.exp(-s5r)


@jax.jit
def neg_mll(params: GPParams, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = x.shape[0]
    k = matern52(x, x, params)
    k = k + (jnp.exp(2 * params.log_noise) + 1e-5) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * jnp.log(2 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("steps",))
def _fit(params0: GPParams, x, y, steps: int = 150, lr: float = 0.05):
    """Adam on the negative MLL."""
    def adam_step(carry, _):
        p, m, v, t = carry
        g = jax.grad(neg_mll)(p, x, y)
        t = t + 1
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, m, g)
        v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg, v, g)
        mh = jax.tree.map(lambda mm: mm / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + 1e-8),
                         p, mh, vh)
        # clamp to sane ranges to keep the Cholesky healthy; reject any
        # step that went NaN (singular K during the line search)
        p = GPParams(jnp.clip(p.log_ls, -3.0, 1.5),
                     jnp.clip(p.log_amp, -3.0, 2.0),
                     jnp.clip(p.log_noise, -5.0, 1.0))
        ok = jnp.all(jnp.stack([jnp.all(jnp.isfinite(x))
                                for x in jax.tree.leaves(p)]))
        prev = carry[0]
        p = jax.tree.map(lambda new, old: jnp.where(ok, new, old), p, prev)
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params0)
    (p, _, _, _), _ = jax.lax.scan(
        adam_step, (params0, zeros, zeros, jnp.zeros((), jnp.int32)),
        None, length=steps)
    return p


def fit_gp(x: np.ndarray, y: np.ndarray, steps: int = 150) -> GPPosterior:
    """x in unit cube (n,d); y raw objective (normalized internally)."""
    x = jnp.asarray(x, jnp.float64 if jax.config.read("jax_enable_x64")
                    else jnp.float32)
    y_raw = jnp.asarray(y, x.dtype)
    y_mean = jnp.mean(y_raw)
    y_std = jnp.maximum(jnp.std(y_raw), 1e-6)
    yn = (y_raw - y_mean) / y_std
    d = x.shape[1]
    p0 = GPParams(jnp.zeros(d) - 0.7, jnp.zeros(()), jnp.zeros(()) - 2.0)
    p = _fit(p0, x, yn, steps=steps)
    n = x.shape[0]
    k = matern52(x, x, p) + (jnp.exp(2 * p.log_noise) + 1e-5) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), yn)
    return GPPosterior(p, x, chol, alpha, y_mean, y_std)


@jax.jit
def predict(post: GPPosterior, xq: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean/stddev at query points (m,d) — in raw y units."""
    kq = matern52(xq, post.x, post.params)                  # (m,n)
    mu = kq @ post.alpha
    v = jax.scipy.linalg.solve_triangular(post.chol, kq.T, lower=True)
    var = jnp.maximum(
        matern52(xq, xq, post.params).diagonal() - jnp.sum(v * v, axis=0),
        1e-12)
    return (mu * post.y_std + post.y_mean,
            jnp.sqrt(var) * post.y_std)


@jax.jit
def expected_improvement(post: GPPosterior, xq: jnp.ndarray,
                         best: jnp.ndarray, xi: float = 0.01) -> jnp.ndarray:
    mu, sd = predict(post, xq)
    z = (mu - best - xi) / sd
    ncdf = 0.5 * (1 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    npdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2 * jnp.pi)
    return (mu - best - xi) * ncdf + sd * npdf
