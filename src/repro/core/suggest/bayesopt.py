"""GP Bayesian optimization with parallel (constant-liar) asking — the
optimizer class the paper builds its infrastructure around (SigOpt serves
Bayesian optimization for parallel workers [9]).

ask(n) returns n *distinct* points even before any results return: each
accepted point is added as a pseudo-observation at the current posterior
mean ("constant liar"), so simultaneous workers spread out instead of
piling onto the same optimum — the core requirement for the paper's
"multiple model configurations simultaneously" workflow.

Hot-path contract (ISSUE 2): ask(n) performs **at most one** hyperparameter
fit per batch — warm-started from the previous optimum — then selects the
whole batch with ``gp.select_batch`` (one jitted q-EI scan with rank-1
constant-liar updates, O(n²) per lie instead of a full refit per point).
Pending lies are keyed by a ``__lie`` token carried in the assignment, so
near-identical suggestions (speculative twins, densified local candidates)
always retire the *right* lie.
"""
from __future__ import annotations

import uuid
from typing import Dict, List, Sequence

import numpy as np

from repro.core.space import Assignment, Space, strip_internal as _clean
from repro.core.suggest import gp
from repro.core.suggest.base import Observation, Optimizer, register

LIE_KEY = "__lie"


@register("gp")
@register("bayesopt")
class BayesOpt(Optimizer):
    expensive_ask = True        # service runs the prefetch pump for us

    def __init__(self, space: Space, seed: int = 0, n_init: int = 8,
                 candidates: int = 1024, fit_steps: int = 150,
                 warm_fit_steps: int = 40, refit_every: int = 4):
        super().__init__(space, seed)
        self.n_init = n_init
        self.n_candidates = candidates
        self.fit_steps = fit_steps
        self.warm_fit_steps = warm_fit_steps
        self.refit_every = refit_every
        self._post = None
        self._params = None                    # warm-start hyperparameters
        self._since_fit = 0
        self._needs_fit = True
        self._needs_recondition = False
        self._n_in_post = 0                    # real + lie rows in posterior
        self._pending: Dict[str, np.ndarray] = {}   # lie key -> unit coords
        # per-instance nonce: a stale token from a pre-restart in-flight
        # trial must never collide with this incarnation's keys
        self._lie_nonce = uuid.uuid4().hex[:8]
        self._lie_seq = 0
        self._xs: List[np.ndarray] = []        # unit coords of successes
        self._ys: List[float] = []
        self._prewarmed = 0                    # largest bucket compiled
        # Service pipeline mode (set by the prefetch pump): ask() never
        # runs a hyperparameter fit once warm-started — new observations
        # are folded by an exact recondition at the current
        # hyperparameters (one O(b³) Cholesky), and the owed refit runs
        # later in maintain() on the pump thread.  Default False: the
        # raw ask/tell contract (one warm fit per ask batch) is unchanged.
        self.defer_fits = False

    # ------------------------------------------------------------------
    def prewarm(self, max_history: int, batch: int = 8) -> int:
        """Compile the jitted GP kernels for every power-of-two bucket up
        to ``bucket_size(max_history)`` (both the cold and warm fit-step
        variants, the rank-1 appends, and the q-EI scan for every batch
        pad up to ``batch``).  Touches no optimizer state — safe to call
        from a background thread while ``ask``/``tell`` run elsewhere,
        since jitted functions cache per shape signature process-wide."""
        target = gp.bucket_size(max(1, int(max_history)))
        k_pads, kp = [], 1
        pad_max = 1 << max(0, int(batch) - 1).bit_length()
        while kp <= pad_max:
            k_pads.append(kp)
            kp *= 2
        m = self.n_candidates + self.n_candidates // 4
        warmed = 0
        b = gp.MIN_BUCKET
        while b <= target:
            if b > self._prewarmed:
                gp.prewarm_bucket(len(self.space), b,
                                  fit_steps=(self.fit_steps,
                                             self.warm_fit_steps),
                                  k_pads=k_pads, n_cand=m)
                warmed += 1
            b *= 2
        self._prewarmed = max(self._prewarmed, target)
        return warmed
    def _new_lie(self, u: np.ndarray) -> str:
        self._lie_seq += 1
        key = f"lie-{self._lie_nonce}-{self._lie_seq:05d}"
        self._pending[key] = np.asarray(u, float)
        return key

    def _free_slots(self) -> int:
        if self._post is None:
            return 0
        return self._post.capacity - self._n_in_post

    def _refit(self, extra: int = 0) -> None:
        """One (warm-started) hyperparameter fit sized so the bucket can
        absorb all pending lies plus ``extra`` upcoming picks, then rank-1
        re-folds of the pending lies.  The only O(steps·n³) call on the
        ask path."""
        if len(self._ys) < max(2, len(self.space)):
            self._post = None
            return
        x = np.asarray(self._xs)
        y = np.asarray(self._ys)
        bucket = gp.bucket_size(len(x) + len(self._pending) + extra)
        steps = (self.warm_fit_steps if self._params is not None
                 else self.fit_steps)
        post = gp.fit_gp(x, y, steps=steps, params0=self._params,
                         bucket=bucket)
        self._params = post.params
        for u in self._pending.values():
            post = gp.append_lie(post, np.asarray(u, np.float32))
        self._post = post
        self._n_in_post = len(x) + len(self._pending)
        self._needs_fit = False
        self._needs_recondition = False
        self._since_fit = 0

    def _recondition(self, extra: int = 0) -> None:
        """Exact posterior rebuild at the *current* hyperparameters (one
        O(b³) Cholesky, no Adam) — drops stale constant-liar rows and
        folds the pending set back in.  The cheap path between the
        every-``refit_every``-observations hyperparameter fits."""
        if self._params is None:
            self._refit(extra=extra)
            return
        x = np.asarray(self._xs)
        y = np.asarray(self._ys)
        bucket = gp.bucket_size(len(x) + len(self._pending) + extra)
        post = gp.make_posterior(self._params, x, y, bucket=bucket)
        for u in self._pending.values():
            post = gp.append_lie(post, np.asarray(u, np.float32))
        self._post = post
        self._n_in_post = len(x) + len(self._pending)
        self._needs_recondition = False

    def maintain(self) -> bool:
        """Run the owed hyperparameter refit, if any (``defer_fits``
        mode).  The service pump calls this off the request path."""
        if self._needs_fit and len(self._ys) >= max(2, len(self.space)):
            self._refit()
            return True
        return False

    def ask(self, n: int = 1) -> List[Assignment]:
        n = int(n)
        if n <= 0:
            return []
        if len(self._ys) < max(self.n_init, 2, len(self.space)):
            return self._ask_random(n)
        if self._post is None or (self._needs_fit
                                  and not (self.defer_fits
                                           and self._params is not None)):
            self._refit(extra=n)
        elif (self._needs_fit or self._needs_recondition
                or self._free_slots() < n):
            # deferred-fit mode: fold the new observations exactly at the
            # current hyperparameters; maintain() pays the fit later
            self._recondition(extra=n)
        if self._post is None:
            return self._ask_random(n)
        cand = self._candidates()
        best_y = np.float32(max(self._ys))
        picks, post = gp.select_batch(self._post, cand, best_y, n)
        self._post = post
        self._n_in_post += n
        out = []
        for j in np.asarray(picks):
            u = np.asarray(cand[int(j)], float)
            a = self.space.from_unit(u)
            a[LIE_KEY] = self._new_lie(u)
            out.append(a)
        return out

    def _ask_random(self, n: int) -> List[Assignment]:
        out = []
        for a in self.space.sample(self.rng, n):
            a[LIE_KEY] = self._new_lie(self.space.to_unit(_clean(a)))
            out.append(a)
        return out

    def _candidates(self) -> np.ndarray:
        d = len(self.space)
        cand = self.rng.uniform(size=(self.n_candidates, d))
        # densify around the incumbent (local exploitation pool); the
        # total is a fixed shape so the q-EI scan compiles once per bucket
        inc = self._xs[int(np.argmax(self._ys))]
        local = np.clip(inc[None] + self.rng.normal(
            0, 0.08, size=(self.n_candidates // 4, d)), 0, 1)
        return np.concatenate([cand, local], axis=0).astype(np.float32)

    def _retire_lie(self, o: Observation) -> bool:
        """Remove the observation's pending lie; True if one was retired."""
        key = None
        if isinstance(o.assignment, dict):
            key = o.assignment.get(LIE_KEY)
        if key is None and o.metadata:
            key = o.metadata.get(LIE_KEY)
        if key is not None:
            return self._pending.pop(key, None) is not None
        # legacy observations without a lie token: nearest-match fallback
        u = self.space.to_unit(_clean(o.assignment))
        for k, pend in self._pending.items():
            if np.allclose(pend, u, atol=1e-6):
                del self._pending[k]
                return True
        return False

    def forget(self, assignment: Assignment) -> None:
        """Retire the lie of a suggestion that will never be observed
        (released / stopped), so it stops suppressing EI at that point."""
        if self._retire_lie(Observation(assignment, None)) \
                and self._post is not None:
            self._needs_recondition = True

    def _update(self, observations: Sequence[Observation]) -> None:
        for o in observations:
            retired = self._retire_lie(o)
            if retired and self._post is not None:
                # the retired lie's row is folded into the posterior; a
                # rank-1 *removal* isn't worth the downdate, so rebuild
                # (cheaply, at current hyperparameters) on the next ask
                # instead of conditioning on both the stale lie and the
                # real value for the same point
                self._needs_recondition = True
            if (not o.failed and o.value is not None
                    and np.isfinite(o.value)):
                u = self.space.to_unit(_clean(o.assignment))
                self._xs.append(u)
                self._ys.append(float(o.value))
                if (not retired and self._post is not None
                        and not self._needs_recondition and not self._needs_fit
                        and self._free_slots() >= 1):
                    # lie-free observation (restore replay / external
                    # tell): exact rank-1 fold, no rebuild needed
                    self._post = gp.append_point(
                        self._post, np.asarray(u, np.float32),
                        np.float32(o.value))
                    self._n_in_post += 1
                elif not retired:
                    self._needs_recondition = True
        self._since_fit += len(observations)
        if self._since_fit >= self.refit_every:
            self._needs_fit = True
