"""GP Bayesian optimization with parallel (constant-liar) asking — the
optimizer class the paper builds its infrastructure around (SigOpt serves
Bayesian optimization for parallel workers [9]).

ask(n) returns n *distinct* points even before any results return: each
accepted point is added as a pseudo-observation at the current posterior
mean ("constant liar"), so simultaneous workers spread out instead of
piling onto the same optimum — the core requirement for the paper's
"multiple model configurations simultaneously" workflow.

Hot-path contract (ISSUE 2): ask(n) performs **at most one** hyperparameter
fit per batch — warm-started from the previous optimum — then selects the
whole batch with ``gp.select_batch`` (one jitted q-EI scan with rank-1
constant-liar updates, O(n²) per lie instead of a full refit per point).
Pending lies are keyed by a ``__lie`` token carried in the assignment, so
near-identical suggestions (speculative twins, densified local candidates)
always retire the *right* lie.

Refit scheduling (ISSUE 5): ``warm_fit_steps``/``refit_every`` are *base*
values of an adaptive schedule rather than fixed constants.  Past
``ADAPT_N`` observations the warm-fit step budget shrinks (the warm start
is near-converged; each Adam step is O(n³)) and the refit period grows
with the history and — in service-pipeline mode — with the measured
fit-latency : observation-arrival ratio, so hyperfits can never consume
more than ~``FIT_DUTY`` of the optimizer's wall-time.  The live schedule
is observable via ``refit_schedule()`` (surfaced in ``StatusResponse``
pump stats).  ``ask(n, speculative=True)`` additionally lets the service
refill its prefetch queue from the sparse subset-of-data posterior
(``gp.sparse_posterior``) when the exact path is saturated.
"""
from __future__ import annotations

import time
import uuid
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.space import Assignment, Space, strip_internal as _clean
from repro.core.suggest import gp
from repro.core.suggest.base import Observation, Optimizer, register

LIE_KEY = "__lie"

#: History size below which the base ``warm_fit_steps``/``refit_every``
#: apply verbatim (small histories: cheap, frequent fits; the adaptive
#: schedule only kicks in past this).  Matches ``gp.SPARSE_MAX`` — the
#: same threshold past which the sparse speculative posterior differs
#: from the exact one.
ADAPT_N = gp.SPARSE_MAX
#: Floor for the adaptive warm-fit step budget.
MIN_WARM_STEPS = 8
#: Ceiling for the adaptive refit period (observations between hyperfits).
MAX_REFIT_EVERY = 64
#: Largest fraction of wall-time (measured as fit-latency over observation
#: inter-arrival time) the deferred hyperfits may consume in pipeline mode.
FIT_DUTY = 0.25

#: Bounds of the live inducing-set budget ladder (ISSUE 8): the service
#: feeds its sparse-vs-exact regret counters back through ``tune_sparse``,
#: halving the subset while sparse quality tracks exact (cheaper refills)
#: and doubling it when it drifts.  The *eligibility* threshold stays the
#: class constant ``gp.SPARSE_MAX`` — tuning changes how much the sparse
#: posterior costs, never when it may serve.
SPARSE_MIN = 16
SPARSE_LADDER_MAX = 2 * gp.SPARSE_MAX
#: Relative slack on the sparse mean regret before the subset grows.
SPARSE_TOL = 0.25
#: Fresh finished-trial observations (per serving class) required between
#: ladder moves — one burst can't walk the budget to a rail.
SPARSE_TUNE_OBS = 8


class FitSpec:
    """Batchable deferred-fit descriptor (ISSUE 8) — what
    ``Optimizer.fit_spec`` snapshots under the optimizer lock for the
    shared FitExecutor.  Specs sharing ``group_key`` may be co-batched
    into one vmap'd dispatch — since the masked variable-step fit loop
    (ISSUE 10) the key is ``(runner, bucket)`` only: lanes on different
    rungs of the adaptive warm-step ladder merge into one ``max(steps)``
    dispatch with per-lane freeze masks.  ``install(params, fit_seconds)``
    is called back under the optimizer lock, preserving the two-phase
    no-mutation contract (compute never touches live state)."""
    kind = "fit"
    __slots__ = ("bucket", "steps", "x", "y", "params0", "install",
                 "runner")

    def __init__(self, bucket, steps, x, y, params0, install, runner):
        self.bucket = int(bucket)
        self.steps = int(steps)
        self.x = x
        self.y = y
        self.params0 = params0
        self.install = install
        self.runner = runner

    @property
    def group_key(self):
        return (self.runner, self.bucket)


def run_fit_lanes(specs: Sequence[FitSpec]):
    """FitExecutor lane runner: fit every spec (all sharing one shape
    bucket) in one ``gp.batched_fit`` dispatch — or the ordinary
    ``fit_gp`` path for a single lane, so a lone refit reuses the
    per-bucket ``_fit`` compiles ``prewarm`` already paid for.  Mixed
    per-lane step counts are fine: the batched fit runs a masked
    ``max(steps)`` loop that freezes each lane at its own budget.
    Returns (list of fitted GPParams, total wall seconds)."""
    t0 = time.perf_counter()
    if len(specs) == 1:
        s = specs[0]
        post = gp.fit_gp(s.x, s.y, steps=s.steps, params0=s.params0,
                         bucket=s.bucket)
        out = [post.params]
    else:
        out = gp.batched_fit([(s.x, s.y, s.params0) for s in specs],
                             steps=[s.steps for s in specs],
                             bucket=specs[0].bucket)
    return out, time.perf_counter() - t0


class AskSpec:
    """Batchable deferred-*ask* descriptor (ISSUE 10) — what
    ``BayesOpt.ask_spec`` snapshots under the optimizer lock so the
    shared FitExecutor can gather queue-refill asks from several
    experiments into ONE vmap'd q-EI dispatch (``gp.batched_select``).
    Specs sharing ``group_key`` — same runner, posterior bucket, scan
    pad and candidate-pool shape — stack on a lane axis and compile
    once per (bucket, k_pad, lane-pad) triple.  ``install(result, dt)``
    — result the lane's ``(picks, posterior)`` pair — is called back
    under the optimizer lock; it mints the suggestions' assignments
    (registering their constant-liar tokens) and either adopts the
    lie-folded posterior (when the optimizer's posterior is unchanged
    since the snapshot) or just marks a recondition — batched refills
    are speculative-queue-only, so the staleness bound contains any
    mid-flight drift exactly as it does for sparse refills."""
    kind = "ask"
    __slots__ = ("bucket", "k", "k_pad", "post", "cand", "best",
                 "install", "runner", "sparse")

    def __init__(self, bucket, k, post, cand, best, install, runner,
                 sparse=False, k_pad=None):
        self.bucket = int(bucket)
        self.k = int(k)
        self.k_pad = int(gp.SELECT_PAD if k_pad is None else k_pad)
        self.post = post
        self.cand = cand
        self.best = best
        self.install = install
        self.runner = runner
        self.sparse = bool(sparse)

    @property
    def group_key(self):
        return (self.runner, self.bucket, self.k_pad,
                tuple(self.cand.shape))


def run_ask_lanes(specs: Sequence[AskSpec]):
    """FitExecutor lane runner for batched refill asks: run every
    spec's q-EI batch selection in one ``gp.batched_select`` dispatch.
    Returns (list of per-lane (picks, posterior) pairs, wall seconds) —
    the executor feeds each pair to its lane's ``install``."""
    t0 = time.perf_counter()
    out = gp.batched_select([(s.post, s.cand, s.best, s.k) for s in specs],
                            k_pad=specs[0].k_pad)
    return out, time.perf_counter() - t0


@register("gp")
@register("bayesopt")
class BayesOpt(Optimizer):
    expensive_ask = True        # service runs the prefetch pump for us
    speculative_ask = True      # honors ask(n, speculative=True)
    batchable_fits = True       # fit_spec() descriptors may co-batch
    batchable_asks = True       # ask_spec() descriptors may co-batch

    def __init__(self, space: Space, seed: int = 0, n_init: int = 8,
                 candidates: int = 1024, fit_steps: int = 150,
                 warm_fit_steps: int = 40, refit_every: int = 4,
                 adaptive: bool = True):
        super().__init__(space, seed)
        self.n_init = n_init
        self.n_candidates = candidates
        self.fit_steps = fit_steps
        self.warm_fit_steps = warm_fit_steps
        self.refit_every = refit_every
        self.adaptive = adaptive
        self._post = None
        self._params = None                    # warm-start hyperparameters
        self._since_fit = 0
        self._needs_fit = True
        self._needs_recondition = False
        self._n_in_post = 0                    # real + lie rows in posterior
        self._pending: Dict[str, np.ndarray] = {}   # lie key -> unit coords
        # per-instance nonce: a stale token from a pre-restart in-flight
        # trial must never collide with this incarnation's keys
        self._lie_nonce = uuid.uuid4().hex[:8]
        self._lie_seq = 0
        self._xs: List[np.ndarray] = []        # unit coords of successes
        self._ys: List[float] = []
        self._prewarmed = 0                    # largest bucket compiled
        # Service pipeline mode (set by the prefetch pump): ask() never
        # runs a hyperparameter fit once warm-started — new observations
        # are folded by an exact recondition at the current
        # hyperparameters (one O(b³) Cholesky), and the owed refit runs
        # later in maintain() on the pump thread.  Default False: the
        # raw ask/tell contract (one warm fit per ask batch) is unchanged.
        self.defer_fits = False
        # --- adaptive refit schedule + sparse speculation (ISSUE 5) ---
        self._fit_ema = None            # EMA of hyperfit wall seconds
        self._arrival_ema = None        # EMA of observation inter-arrival s
        self._last_obs_t = None
        self._fits = 0                  # hyperfits run (cold + warm)
        self._sparse_post = None        # cached subset-of-data posterior
        self._sparse_rows = 0           # rows folded into _sparse_post
        self._sparse_m = 0              # subset size of the cached sparse
        self._sparse_asks = 0           # speculative points served sparse
        self._sparse_max = gp.SPARSE_MAX  # live inducing-set budget
        self._sparse_tune_mark = None   # quality counters at last tune

    # ------------------------------------------------- refit schedule
    def warm_steps(self) -> int:
        """Adaptive warm-fit step budget: the base ``warm_fit_steps`` up
        to ``ADAPT_N`` observations, then shrinking ~1/n (each Adam step
        costs O(n³) and the warm start is near-converged), floored at
        ``MIN_WARM_STEPS``."""
        return self._warm_steps_at(len(self._ys))

    def _warm_steps_at(self, n: int) -> int:
        """The schedule as a pure function of history size (``prewarm``
        evaluates it at future sizes).  A halving ladder, not a smooth
        1/n: ``_fit`` is jitted with a static step count, so the schedule
        must only ever take a few discrete values (all prewarmed) or it
        would recompile per history size."""
        s = self.warm_fit_steps
        if not self.adaptive:
            return s
        h = ADAPT_N
        while n > h and s // 2 >= MIN_WARM_STEPS:
            s //= 2
            h *= 2
        return s

    def refit_period(self) -> int:
        """Adaptive refit period: the base ``refit_every`` up to
        ``ADAPT_N`` observations, then growing with the history
        (hyperparameters move slowly once the posterior is data-rich) and
        — in ``defer_fits`` pipeline mode — with the measured
        fit-latency : arrival-rate ratio so deferred hyperfits stay under
        a ``FIT_DUTY`` share of wall-time under sustained load."""
        n = len(self._ys)
        if not self.adaptive or n <= ADAPT_N:
            return self.refit_every
        period = max(self.refit_every, n // 16)
        if (self.defer_fits and self._fit_ema is not None
                and self._arrival_ema is not None and self._arrival_ema > 0):
            period = max(period, int(np.ceil(
                self._fit_ema / (self._arrival_ema * FIT_DUTY))))
        return min(period, MAX_REFIT_EVERY)

    def refit_schedule(self) -> Dict[str, object]:
        """Live schedule readout (StatusResponse pump stats)."""
        ms = (lambda s: None if s is None else round(s * 1e3, 3))
        return {"n": len(self._ys), "warm_steps": self.warm_steps(),
                "refit_every": self.refit_period(),
                "since_fit": self._since_fit, "fits": self._fits,
                "fit_ms": ms(self._fit_ema),
                "arrival_ms": ms(self._arrival_ema),
                "sparse_asks": self._sparse_asks,
                "sparse_m": self._sparse_m,
                "sparse_max": self._sparse_max}

    # ------------------------------------------------------------------
    def prewarm(self, max_history: int, batch: int = 8) -> int:
        """Compile the jitted GP kernels for every power-of-two bucket up
        to ``bucket_size(max_history)`` (both the cold and warm fit-step
        variants, the rank-1 appends, and the q-EI scan for every batch
        pad up to ``batch``).  Touches no optimizer state — safe to call
        from a background thread while ``ask``/``tell`` run elsewhere,
        since jitted functions cache per shape signature process-wide.

        The solo ``fit_lanes=(1,)`` executor variant is warmed too:
        since the FitExecutor routes every refit through ``batched_fit``,
        the lane-pad-1 compile otherwise lands mid-run — off the request
        path, but on a saturated box it still stalls in-flight requests
        for the compile's duration.  Multi-lane pads stay lazy (they only
        occur when experiments co-batch).  The batched-ask scan is warmed
        at ``select_lanes=(1, 2)`` (ISSUE 10): every executor refill
        dispatch runs through ``batched_select``, so lane pads 1 and 2 —
        the overwhelmingly common co-batch widths — must never compile
        mid-run; wider pads stay lazy for the same reason as fit lanes."""
        target = gp.bucket_size(max(1, int(max_history)))
        k_pads, kp = [], 1
        pad_max = 1 << max(0, int(batch) - 1).bit_length()
        while kp <= pad_max:
            k_pads.append(kp)
            kp *= 2
        m = self.n_candidates + self.n_candidates // 4
        warmed = 0
        b = gp.MIN_BUCKET
        while b <= target:
            if b > self._prewarmed:
                # only the warm-step ladder values reachable while the
                # history lives in this bucket (plus the cold fit) — not
                # the whole ladder per bucket
                gp.prewarm_bucket(len(self.space), b,
                                  fit_steps=(self.fit_steps,
                                             self._warm_steps_at(b // 2),
                                             self._warm_steps_at(b)),
                                  k_pads=k_pads, n_cand=m, fit_lanes=(1,),
                                  select_lanes=(1, 2))
                warmed += 1
            b *= 2
        self._prewarmed = max(self._prewarmed, target)
        return warmed
    def _new_lie(self, u: np.ndarray) -> str:
        self._lie_seq += 1
        key = f"lie-{self._lie_nonce}-{self._lie_seq:05d}"
        self._pending[key] = np.asarray(u, float)
        return key

    def _free_slots(self) -> int:
        if self._post is None:
            return 0
        return self._post.capacity - self._n_in_post

    def _refit(self, extra: int = 0) -> None:
        """One (warm-started) hyperparameter fit sized so the bucket can
        absorb all pending lies plus ``extra`` upcoming picks, then rank-1
        re-folds of the pending lies.  The only O(steps·n³) call on the
        ask path."""
        if len(self._ys) < max(2, len(self.space)):
            self._post = None
            return
        x = np.asarray(self._xs)
        y = np.asarray(self._ys)
        bucket = gp.bucket_size(len(x) + len(self._pending) + extra)
        steps = (self.warm_steps() if self._params is not None
                 else self.fit_steps)
        t0 = time.perf_counter()
        post = gp.fit_gp(x, y, steps=steps, params0=self._params,
                         bucket=bucket)
        dt = time.perf_counter() - t0
        self._fit_ema = dt if self._fit_ema is None \
            else 0.7 * self._fit_ema + 0.3 * dt
        self._fits += 1
        self._params = post.params
        for u in self._pending.values():
            post = gp.append_lie(post, np.asarray(u, np.float32))
        self._post = post
        self._sparse_post = None        # new hyperparameters
        self._n_in_post = len(x) + len(self._pending)
        self._needs_fit = False
        self._needs_recondition = False
        self._since_fit = 0

    def _recondition(self, extra: int = 0) -> None:
        """Exact posterior rebuild at the *current* hyperparameters (one
        O(b³) Cholesky, no Adam) — drops stale constant-liar rows and
        folds the pending set back in.  The cheap path between the
        every-``refit_every``-observations hyperparameter fits."""
        if self._params is None:
            self._refit(extra=extra)
            return
        x = np.asarray(self._xs)
        y = np.asarray(self._ys)
        bucket = gp.bucket_size(len(x) + len(self._pending) + extra)
        post = gp.make_posterior(self._params, x, y, bucket=bucket)
        for u in self._pending.values():
            post = gp.append_lie(post, np.asarray(u, np.float32))
        self._post = post
        self._n_in_post = len(x) + len(self._pending)
        self._needs_recondition = False

    def maintenance_due(self) -> bool:
        """True when a deferred hyperparameter refit is owed — what the
        service pump checks before queueing a job on the shared fit
        executor."""
        return self._needs_fit and len(self._ys) >= max(2, len(self.space))

    def maintain(self) -> bool:
        """Run the owed hyperparameter refit, if any (``defer_fits``
        mode), inline and under the caller's lock.  The service's shared
        fit executor prefers ``fit_job`` (lock-free compute)."""
        if self.maintenance_due():
            self._refit()
            return True
        return False

    def fit_spec(self) -> Optional[FitSpec]:
        """Snapshot the owed hyperparameter fit as a batchable
        ``FitSpec`` (ISSUE 8) — arrays copied under the caller's lock,
        so the executor may run the fit (alone or co-batched with other
        experiments sharing the (bucket, steps) group) with no lock
        held.  ``spec.install(params, dt)`` must be called back under
        the optimizer lock: it only adopts the new hyperparameters and
        marks a recondition; the next ``ask`` folds them together with
        any observations that arrived mid-fit, so a lane whose
        experiment saw a mid-fit burst just re-arms."""
        if not self.maintenance_due():
            return None
        x = np.asarray(self._xs)
        y = np.asarray(self._ys)
        params0 = self._params
        steps = self.warm_steps() if params0 is not None else self.fit_steps
        bucket = gp.bucket_size(len(x))
        n_snap = len(y)

        def install(params, dt):
            self._fit_ema = dt if self._fit_ema is None \
                else 0.7 * self._fit_ema + 0.3 * dt
            self._fits += 1
            self._params = params
            self._sparse_post = None
            # observations that landed mid-fit stay counted as debt —
            # and if they already exceed the period (a burst arrived
            # during the fit), the next fit is owed immediately, else
            # the MAX_REFIT_EVERY staleness bound would silently slip
            self._since_fit = max(0, len(self._ys) - n_snap)
            self._needs_fit = self._since_fit >= self.refit_period()
            self._needs_recondition = True

        return FitSpec(bucket=bucket, steps=steps, x=x, y=y,
                       params0=params0, install=install,
                       runner=run_fit_lanes)

    def fit_job(self):
        """Snapshot the owed hyperparameter fit as a lock-free closure
        (ISSUE 5): the caller invokes the returned ``run()`` WITHOUT
        holding the optimizer lock — it is pure JAX compute over copied
        arrays — and then applies the ``install()`` it returns under the
        lock.  Single-lane view of ``fit_spec`` (same snapshot, same
        install semantics)."""
        spec = self.fit_spec()
        if spec is None:
            return None

        def run():
            out, dt = run_fit_lanes([spec])

            def install():
                spec.install(out[0], dt)
            return install
        return run

    # ----------------------------------------------------- batchable ask
    def ask_spec_ready(self) -> bool:
        """Whether ``ask_spec`` would yield a batchable refill right now
        — the service pump checks this (under the optimizer lock) before
        routing a queue refill through the shared executor instead of an
        inline ``ask``.  Only the random init phase is excluded: random
        suggestions are cheap and carry no posterior to batch."""
        return len(self._ys) >= max(self.n_init, 2, len(self.space))

    def ask_spec(self, n: int = 1,
                 speculative: bool = False) -> Optional["AskSpec"]:
        """Snapshot a queue-refill ask as a batchable ``AskSpec``
        (ISSUE 10).  Performs exactly the posterior preparation ``ask``
        would — recondition / sparse rebuild under the caller-held
        optimizer lock — but *defers the q-EI selection scan* to the
        executor, which may co-batch it with other experiments' refills
        into one ``gp.batched_select`` dispatch.  ``spec.install`` must
        be called back under the optimizer lock; it returns the minted
        assignments (lie tokens registered, exactly as ``ask`` would
        have produced).  Returns None outside the model phase or when
        ``n`` exceeds the fixed ``gp.SELECT_PAD`` scan pad."""
        n = int(n)
        if n <= 0 or n > gp.SELECT_PAD or not self.ask_spec_ready():
            return None
        sparse = bool(speculative and self.sparse_eligible())
        if sparse:
            if (self._sparse_post is None
                    or self._sparse_post.capacity - self._sparse_rows < n):
                self._sparse_recondition(extra=n)
            post = self._sparse_post
        else:
            if self._post is None or (self._needs_fit
                                      and not (self.defer_fits
                                               and self._params is not None)):
                self._refit(extra=n)
            elif (self._needs_fit or self._needs_recondition
                    or self._free_slots() < n):
                self._recondition(extra=n)
            post = self._post
            if post is None:
                return None
        cand = self._candidates()
        best = float(max(self._ys))

        def install(result, dt):
            picks, lane_post = result
            out = []
            for j in np.asarray(picks):
                u = np.asarray(cand[int(j)], float)
                a = self.space.from_unit(u)
                a[LIE_KEY] = self._new_lie(u)
                out.append(a)
            if sparse:
                if self._sparse_post is post:
                    # nothing moved mid-dispatch: adopt the lie-folded
                    # sparse posterior — the exact fast path
                    self._sparse_post = lane_post
                    self._sparse_rows += n
                else:
                    self._sparse_post = None
                self._sparse_asks += n
                self._needs_recondition = True
            else:
                if self._post is post and not self._needs_recondition:
                    self._post = lane_post
                    self._n_in_post += n
                else:
                    # the posterior moved while the dispatch was in
                    # flight (observation fold / forget): the minted
                    # lies are registered but not folded — the next
                    # exact ask reconditions with the full pending set.
                    # Safe because batched refills only feed the
                    # staleness-bounded speculative queue.
                    self._needs_recondition = True
                self._sparse_post = None
            return out

        return AskSpec(bucket=post.capacity, k=n, post=post, cand=cand,
                       best=best, install=install, runner=run_ask_lanes,
                       sparse=sparse)

    def ask(self, n: int = 1, speculative: bool = False) -> List[Assignment]:
        n = int(n)
        if n <= 0:
            return []
        if len(self._ys) < max(self.n_init, 2, len(self.space)):
            return self._ask_random(n)
        if speculative and self.sparse_eligible():
            return self._ask_sparse(n)
        if self._post is None or (self._needs_fit
                                  and not (self.defer_fits
                                           and self._params is not None)):
            self._refit(extra=n)
        elif (self._needs_fit or self._needs_recondition
                or self._free_slots() < n):
            # deferred-fit mode: fold the new observations exactly at the
            # current hyperparameters; maintain() pays the fit later
            self._recondition(extra=n)
        if self._post is None:
            return self._ask_random(n)
        cand = self._candidates()
        best_y = np.float32(max(self._ys))
        picks, post = gp.select_batch(self._post, cand, best_y, n)
        self._post = post
        self._n_in_post += n
        # the new exact-path lies are not in the cached sparse posterior:
        # a later speculative refill must rebuild it or it could re-pick
        # these very points
        self._sparse_post = None
        out = []
        for j in np.asarray(picks):
            u = np.asarray(cand[int(j)], float)
            a = self.space.from_unit(u)
            a[LIE_KEY] = self._new_lie(u)
            out.append(a)
        return out

    # ------------------------------------------- sparse speculative ask
    def sparse_eligible(self) -> bool:
        """Whether ``ask(n, speculative=True)`` would actually take the
        sparse path right now — the service checks this so its
        ``sparse_prefilled``/``sparse_served`` counters only ever count
        genuinely sparse suggestions.  The sparse path only exists to
        break refit-bound saturation: it needs already-fit
        hyperparameters, a history large enough that the subset actually
        differs in cost (past ``gp.SPARSE_MAX`` the exact Cholesky
        outgrows the sparse one), and pipeline mode (the exact posterior
        still serves synchronous asks and misses)."""
        return (self.defer_fits and self._params is not None
                and len(self._ys) > gp.SPARSE_MAX)

    def tune_sparse(self, quality: Dict[str, float]) -> Optional[int]:
        """Feed the service's sparse-vs-exact quality counters (cumulative
        finished-trial counts + summed instantaneous regret, maintained at
        observe time) back into the live inducing-set budget — the PR 5
        follow-up (ISSUE 8).  Compares the *windowed* mean regret since
        the last ladder move: while sparse-served suggestions regret no
        more than ``1+SPARSE_TOL`` times the exact-served ones (plus a
        small absolute slack at the objective's scale), the subset halves
        — cheaper refills at no measured quality cost; when it drifts
        past the tolerance, it doubles back.  Moves one ladder step per
        ``SPARSE_TUNE_OBS`` fresh observations of each class, clamped to
        [SPARSE_MIN, SPARSE_LADDER_MAX].  Returns the new budget when it
        changed, else None.  Call under the optimizer lock."""
        s_n = int(quality.get("sparse_obs", 0) or 0)
        s_r = float(quality.get("sparse_regret", 0.0) or 0.0)
        e_n = int(quality.get("exact_obs", 0) or 0)
        e_r = float(quality.get("exact_regret", 0.0) or 0.0)
        if self._sparse_tune_mark is None:
            self._sparse_tune_mark = (s_n, s_r, e_n, e_r)
            return None
        m_sn, m_sr, m_en, m_er = self._sparse_tune_mark
        d_sn, d_en = s_n - m_sn, e_n - m_en
        if d_sn < SPARSE_TUNE_OBS or d_en < SPARSE_TUNE_OBS:
            return None
        self._sparse_tune_mark = (s_n, s_r, e_n, e_r)
        mean_s = (s_r - m_sr) / d_sn
        mean_e = (e_r - m_er) / d_en
        # absolute slack: regret means near zero (a converged experiment)
        # must not read as drift from float dust — scale by the objective
        slack = 0.05 * (float(np.std(self._ys)) if len(self._ys) > 1
                        else 1.0)
        cur = self._sparse_max
        if mean_s <= mean_e * (1.0 + SPARSE_TOL) + slack:
            new = max(SPARSE_MIN, cur // 2)
        else:
            new = min(SPARSE_LADDER_MAX, cur * 2)
        if new == cur:
            return None
        self._sparse_max = new
        self._sparse_post = None        # rebuild at the new budget
        return new

    def _sparse_recondition(self, extra: int) -> None:
        """(Re)build the cached subset-of-data posterior at the current
        hyperparameters and fold the pending lies in — O(m³) with
        m <= the live ``_sparse_max`` budget, independent of history
        size."""
        post, idx = gp.sparse_posterior(self._params, np.asarray(self._xs),
                                        np.asarray(self._ys),
                                        m=self._sparse_max,
                                        extra=len(self._pending) + extra)
        for u in self._pending.values():
            post = gp.append_lie(post, np.asarray(u, np.float32))
        self._sparse_post = post
        self._sparse_m = len(idx)
        self._sparse_rows = len(idx) + len(self._pending)

    def _ask_sparse(self, n: int) -> List[Assignment]:
        """Select a speculative batch from the sparse posterior (one
        bounded Cholesky + the same jitted q-EI scan), leaving the exact
        posterior untouched.  Lies are registered exactly like exact-path
        lies, so retirement/recondition see no difference."""
        if (self._sparse_post is None
                or self._sparse_post.capacity - self._sparse_rows < n):
            self._sparse_recondition(extra=n)
        cand = self._candidates()
        best_y = np.float32(max(self._ys))
        picks, post = gp.select_batch(self._sparse_post, cand, best_y, n)
        self._sparse_post = post
        self._sparse_rows += n
        self._sparse_asks += n
        # the new lies live only in the sparse posterior: the next exact
        # ask must fold the full pending set back in before selecting
        self._needs_recondition = True
        out = []
        for j in np.asarray(picks):
            u = np.asarray(cand[int(j)], float)
            a = self.space.from_unit(u)
            a[LIE_KEY] = self._new_lie(u)
            out.append(a)
        return out

    def _ask_random(self, n: int) -> List[Assignment]:
        out = []
        for a in self.space.sample(self.rng, n):
            a[LIE_KEY] = self._new_lie(self.space.to_unit(_clean(a)))
            out.append(a)
        self._sparse_post = None    # lies the sparse cache hasn't seen
        return out

    def _candidates(self) -> np.ndarray:
        d = len(self.space)
        cand = self.rng.uniform(size=(self.n_candidates, d))
        # densify around the incumbent (local exploitation pool); the
        # total is a fixed shape so the q-EI scan compiles once per bucket
        inc = self._xs[int(np.argmax(self._ys))]
        local = np.clip(inc[None] + self.rng.normal(
            0, 0.08, size=(self.n_candidates // 4, d)), 0, 1)
        return np.concatenate([cand, local], axis=0).astype(np.float32)

    def _retire_lie(self, o: Observation) -> bool:
        """Remove the observation's pending lie; True if one was retired."""
        key = None
        if isinstance(o.assignment, dict):
            key = o.assignment.get(LIE_KEY)
        if key is None and o.metadata:
            key = o.metadata.get(LIE_KEY)
        if key is not None:
            return self._pending.pop(key, None) is not None
        # legacy observations without a lie token: nearest-match fallback
        u = self.space.to_unit(_clean(o.assignment))
        for k, pend in self._pending.items():
            if np.allclose(pend, u, atol=1e-6):
                del self._pending[k]
                return True
        return False

    def forget(self, assignment: Assignment) -> None:
        """Retire the lie of a suggestion that will never be observed
        (released / stopped), so it stops suppressing EI at that point."""
        if self._retire_lie(Observation(assignment, None)):
            self._sparse_post = None
            if self._post is not None:
                self._needs_recondition = True

    def _update(self, observations: Sequence[Observation]) -> None:
        if observations:
            # arrival-rate EMA for the latency-aware refit period; batch
            # replays (restore) collapse to one arrival sample
            now = time.monotonic()
            if self._last_obs_t is not None:
                dt = max(now - self._last_obs_t, 1e-6) / len(observations)
                self._arrival_ema = dt if self._arrival_ema is None \
                    else 0.7 * self._arrival_ema + 0.3 * dt
            self._last_obs_t = now
            self._sparse_post = None    # data changed
        for o in observations:
            retired = self._retire_lie(o)
            if retired and self._post is not None:
                # the retired lie's row is folded into the posterior; a
                # rank-1 *removal* isn't worth the downdate, so rebuild
                # (cheaply, at current hyperparameters) on the next ask
                # instead of conditioning on both the stale lie and the
                # real value for the same point
                self._needs_recondition = True
            if (not o.failed and o.value is not None
                    and np.isfinite(o.value)):
                u = self.space.to_unit(_clean(o.assignment))
                self._xs.append(u)
                self._ys.append(float(o.value))
                if (not retired and self._post is not None
                        and not self._needs_recondition and not self._needs_fit
                        and self._free_slots() >= 1):
                    # lie-free observation (restore replay / external
                    # tell): exact rank-1 fold, no rebuild needed
                    self._post = gp.append_point(
                        self._post, np.asarray(u, np.float32),
                        np.float32(o.value))
                    self._n_in_post += 1
                elif not retired:
                    self._needs_recondition = True
        self._since_fit += len(observations)
        if self._since_fit >= self.refit_period():
            self._needs_fit = True
