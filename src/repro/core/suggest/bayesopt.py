"""GP Bayesian optimization with parallel (constant-liar) asking — the
optimizer class the paper builds its infrastructure around (SigOpt serves
Bayesian optimization for parallel workers [9]).

ask(n) returns n *distinct* points even before any results return: each
accepted point is added as a pseudo-observation at the current posterior
mean ("constant liar"), so simultaneous workers spread out instead of
piling onto the same optimum — the core requirement for the paper's
"multiple model configurations simultaneously" workflow.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.space import Assignment, Space
from repro.core.suggest import gp
from repro.core.suggest.base import Observation, Optimizer, register


@register("gp")
@register("bayesopt")
class BayesOpt(Optimizer):
    def __init__(self, space: Space, seed: int = 0, n_init: int = 8,
                 candidates: int = 1024, fit_steps: int = 150,
                 refit_every: int = 1):
        super().__init__(space, seed)
        self.n_init = n_init
        self.n_candidates = candidates
        self.fit_steps = fit_steps
        self.refit_every = refit_every
        self._post = None
        self._since_fit = 0
        self._pending: List[np.ndarray] = []   # constant-liar points

    # ------------------------------------------------------------------
    def _design_matrix(self):
        xs, ys = [], []
        for o in self.successes:
            xs.append(self.space.to_unit(
                {k: v for k, v in o.assignment.items()
                 if not k.startswith("__")}))
            ys.append(o.value)
        return np.array(xs), np.array(ys)

    def _refit(self):
        x, y = self._design_matrix()
        if len(x) < max(2, len(self.space)):
            self._post = None
            return
        # constant liar: pending suggestions pinned at the posterior mean
        if self._pending and self._post is not None:
            lie_mu, _ = gp.predict(self._post, np.array(self._pending))
            x = np.concatenate([x, np.array(self._pending)], axis=0)
            y = np.concatenate([y, np.asarray(lie_mu)])
        self._post = gp.fit_gp(x, y, steps=self.fit_steps)

    def ask(self, n: int = 1) -> List[Assignment]:
        out = []
        for _ in range(n):
            if len(self.successes) < self.n_init or self._post is None:
                a = self.space.sample(self.rng, 1)[0]
                self._pending.append(self.space.to_unit(a))
                out.append(a)
                continue
            cand = self._candidates()
            best_y = max(o.value for o in self.successes)
            ei = np.asarray(gp.expected_improvement(
                self._post, cand, np.float32(best_y)))
            pick = cand[int(np.argmax(ei))]
            self._pending.append(np.array(pick))
            self._refit()                       # fold the lie in
            out.append(self.space.from_unit(np.asarray(pick)))
        return out

    def _candidates(self) -> np.ndarray:
        d = len(self.space)
        cand = self.rng.uniform(size=(self.n_candidates, d))
        # densify around the incumbent (local exploitation pool)
        inc = self.space.to_unit(
            {k: v for k, v in self.best().assignment.items()
             if not k.startswith("__")})
        local = np.clip(inc[None] + self.rng.normal(
            0, 0.08, size=(self.n_candidates // 4, d)), 0, 1)
        return np.concatenate([cand, local], axis=0).astype(np.float32)

    def _update(self, observations: Sequence[Observation]) -> None:
        # retire matching pending lies
        for o in observations:
            u = self.space.to_unit(
                {k: v for k, v in o.assignment.items()
                 if not k.startswith("__")})
            for i, pend in enumerate(self._pending):
                if np.allclose(pend, u, atol=1e-6):
                    self._pending.pop(i)
                    break
        self._since_fit += len(observations)
        if self._since_fit >= self.refit_every:
            self._since_fit = 0
            self._refit()
