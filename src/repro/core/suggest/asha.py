"""ASHA-style asynchronous successive halving (paper §2.5: stop bad trials
early and free their resources).

This is a server-side :class:`~repro.core.suggest.base.StoppingPolicy`: the
suggestion service owns ONE instance per experiment, every worker's
``ctx.report(step, value)`` flows into it, and its rung table is
JSON-serializable so it survives service restarts (snapshot + metric-log
replay, exactly like the observation log).

Semantics:
* rungs are ``min_steps * eta**i``; a trial is *recorded* at a rung the
  first time a report's step reaches it, and must then be within the top
  ``1/eta`` of all values recorded at that rung to proceed;
* a report whose step jumps past several rungs is evaluated at every
  crossed rung up to its first failure — a stop at a low rung can never
  be masked by a pass at a higher one, and the value is never recorded
  above the failing rung (an unpromoted trial must not pad higher-rung
  populations);
* ``mode='stop'`` (default) makes the decision final; ``mode='pause'``
  answers ``'pause'`` instead, i.e. the classic promotion-based ASHA: the
  trial's resources are released but its suggestion stays pending, and a
  later re-report at the same rung is re-evaluated against the *current*
  rung population (promotion when enough worse trials arrived).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Set

from repro.core.suggest.base import StoppingPolicy, register_stopping


@register_stopping("asha")
class ASHA(StoppingPolicy):
    def __init__(self, min_steps: int = 1, eta: int = 3, max_rungs: int = 6,
                 goal: str = "max", mode: str = "stop"):
        if mode not in ("stop", "pause"):
            raise ValueError(f"mode must be 'stop' or 'pause', got {mode!r}")
        self.eta = eta
        self.goal = goal
        self.mode = mode
        self.min_steps = min_steps
        self.rungs: List[int] = [min_steps * eta ** i for i in range(max_rungs)]
        self.version = 0
        self._values: Dict[int, List[float]] = {r: [] for r in self.rungs}
        self._recorded: Dict[str, Set[int]] = {}   # trial -> rungs recorded
        self._stopped: Set[str] = set()            # final decisions (mode=stop)

    # ------------------------------------------------------------- reporting
    def report(self, trial_id: str, step: int, value: float) -> str:
        """Returns 'continue' | 'stop' | 'pause'."""
        if trial_id in self._stopped:
            return "stop"
        v = value if self.goal == "max" else -value
        rec = self._recorded.setdefault(trial_id, set())
        failed_rung = None
        for rung in self.rungs:
            if step < rung:
                break
            vals = self._values[rung]
            newly = rung not in rec
            if newly:
                rec.add(rung)
                vals.append(v)
                self.version += 1
            # stop mode judges each rung exactly once, when first crossed:
            # a between-rung report (noisy dip, speculative twin catching
            # up) must not retro-fail a rung the trial already passed.
            # pause mode re-evaluates recorded rungs against the CURRENT
            # population — that re-check is the promotion mechanism for
            # resumed trials.
            if not newly and self.mode == "stop":
                continue
            k = max(1, len(vals) // self.eta)
            top_k = sorted(vals, reverse=True)[:k]
            if v < top_k[-1]:
                failed_rung = rung
                # never record above the first failing rung: the trial is
                # not promoted past it, so padding higher rungs would
                # loosen their top-1/eta cut for everyone else
                break
        if failed_rung is None:
            return "continue"
        if self.mode == "pause":
            return "pause"
        self._stopped.add(trial_id)
        self.version += 1
        return "stop"

    def next_rung(self, trial_id: str) -> Optional[int]:
        rec = self._recorded.get(trial_id, ())
        for rung in self.rungs:
            if rung not in rec:
                return rung
        return None

    # ----------------------------------------------------- snapshot/restore
    def state(self) -> Dict[str, Any]:
        return {"policy": "asha", "eta": self.eta, "goal": self.goal,
                "mode": self.mode, "min_steps": self.min_steps,
                "rungs": list(self.rungs),
                "values": {str(r): list(v) for r, v in self._values.items()
                           if v},
                "recorded": {t: sorted(r) for t, r in self._recorded.items()
                             if r},
                "stopped": sorted(self._stopped)}

    def restore(self, state: Dict[str, Any]) -> None:
        self.rungs = [int(r) for r in state.get("rungs", self.rungs)]
        self._values = {r: [] for r in self.rungs}
        for r, vals in state.get("values", {}).items():
            self._values[int(r)] = [float(v) for v in vals]
        self._recorded = {t: set(int(r) for r in rs)
                          for t, rs in state.get("recorded", {}).items()}
        self._stopped = set(state.get("stopped", []))
        self.version += 1
