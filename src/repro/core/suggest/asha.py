"""ASHA-style asynchronous successive halving (paper §2.5: stop bad trials
early and free their resources).

Usage: trials call ``report(trial_id, rung_step, value)`` periodically; the
stopper answers continue/stop.  A trial stops when it reaches a rung and its
value is outside the top 1/eta of completed values at that rung.
"""
from __future__ import annotations

from typing import Dict, List


class ASHA:
    def __init__(self, min_steps: int = 1, eta: int = 3, max_rungs: int = 6,
                 goal: str = "max"):
        self.eta = eta
        self.goal = goal
        self.rungs: List[int] = [min_steps * eta ** i for i in range(max_rungs)]
        self._values: Dict[int, List[float]] = {r: [] for r in self.rungs}
        self._reported: Dict[str, int] = {}   # trial -> highest rung passed

    def report(self, trial_id: str, step: int, value: float) -> str:
        """Returns 'continue' or 'stop'."""
        v = value if self.goal == "max" else -value
        for rung in self.rungs:
            if step >= rung and self._reported.get(trial_id, -1) < rung:
                self._reported[trial_id] = rung
                vals = self._values[rung]
                vals.append(v)
                k = max(1, len(vals) // self.eta)
                top_k = sorted(vals, reverse=True)[:k]
                if v < top_k[-1]:
                    return "stop"
        return "continue"
