"""Optimizer interface (ask/tell) + registry.

Conventions:
* maximization (the experiment config's goal='min' negates values upstream);
* failed observations carry value=None and are fed back to optimizers so
  they can avoid re-suggesting broken regions (paper §2.5: HPO surfaces
  model bugs as failed observations);
* ask() may be called concurrently with outstanding suggestions (parallel
  bandwidth) — optimizers must not block on pending results.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.space import Assignment, Space


@dataclass
class Observation:
    assignment: Assignment
    value: Optional[float]                 # None => failed
    stddev: float = 0.0
    failed: bool = False
    metadata: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"assignment": self.assignment, "value": self.value,
                "stddev": self.stddev, "failed": self.failed,
                "metadata": self.metadata}

    @classmethod
    def from_json(cls, d) -> "Observation":
        return cls(d["assignment"], d.get("value"), d.get("stddev", 0.0),
                   d.get("failed", False), d.get("metadata", {}))


class Optimizer(abc.ABC):
    #: True when ``ask`` costs enough (model fit / compile) that the
    #: suggestion service should run its prefetch pump for this optimizer.
    expensive_ask: bool = False
    #: True when ``ask`` accepts ``speculative=True`` — a cheaper,
    #: approximate proposal path (e.g. the GP's sparse subset-of-data
    #: posterior) the service may use to refill its prefetch queue when
    #: the exact path is saturated.  Synchronous asks and coalesced
    #: misses always use the exact path.
    speculative_ask: bool = False

    def sparse_eligible(self) -> bool:
        """True when ``ask(n, speculative=True)`` would actually use the
        approximate path *right now* (enough history, fitted model, …).
        The service checks this before labeling refills as sparse, so
        its sparse-traffic counters never count exact suggestions."""
        return False

    def __init__(self, space: Space, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.history: List[Observation] = []

    @abc.abstractmethod
    def ask(self, n: int = 1) -> List[Assignment]:
        ...

    def tell(self, observations: Sequence[Observation]) -> None:
        self.history.extend(observations)
        self._update(observations)

    def _update(self, observations: Sequence[Observation]) -> None:
        pass

    def forget(self, assignment: Assignment) -> None:
        """A previously-asked suggestion will never be observed (released
        back to the budget / experiment stopped): optimizers may drop any
        per-suggestion bookkeeping (e.g. constant-liar lies)."""

    def prewarm(self, max_history: int, batch: int = 8) -> int:
        """Move one-time setup cost (XLA compiles of the ask path) off the
        request path, sized for up to ``max_history`` observations and
        ``ask(batch)``-shaped requests.  Called by the suggestion
        service's prefetch pump at experiment creation and again as the
        history approaches the next shape bucket.  Returns the number of
        shape buckets newly warmed (0 = nothing to do)."""
        return 0

    def maintain(self) -> bool:
        """Perform deferred model maintenance (e.g. a pending
        hyperparameter refit) — the slow work a ``defer_fits`` optimizer
        keeps off the ``ask`` path.  Called by the suggestion service's
        pump when no request is waiting on the optimizer.  Returns True
        when work was done (callers may loop)."""
        return False

    def maintenance_due(self) -> bool:
        """True when deferred maintenance is owed — the cheap check the
        suggestion service makes before queueing a ``maintain`` job on
        the shared fit executor (see ``repro.api.pipeline.FitExecutor``).
        Must not touch model state."""
        return False

    #: True when ``fit_spec`` returns batchable descriptors the shared
    #: fit executor may co-batch across experiments (one vmap'd dispatch
    #: per (runner, bucket, steps) group — see ISSUE 8).  Optimizers
    #: without the split keep the plain two-phase ``fit_job`` path.
    batchable_fits: bool = False

    def fit_spec(self):
        """Snapshot the owed maintenance as a batchable fit descriptor
        (``repro.core.suggest.bayesopt.FitSpec``-shaped: bucket, steps,
        arrays, a lane ``runner``, and an ``install(params, dt)``
        callback applied under the optimizer lock), or None.  Only
        meaningful when ``batchable_fits`` is True."""
        return None

    def fit_job(self):
        """Snapshot the owed maintenance as a two-phase job for the
        shared fit executor: ``fit_job()`` is called under the service's
        optimizer lock and returns None (nothing owed) or a ``run``
        callable; ``run()`` executes WITHOUT the lock (pure compute over
        copied state) and returns an ``install`` callable the executor
        applies under the lock.  The default wraps ``maintain`` so
        optimizers without a lock-free split still work — their compute
        just runs inside the install phase."""
        if not self.maintenance_due():
            return None

        def run():
            return lambda: self.maintain()
        return run

    def refit_schedule(self) -> Optional[Dict[str, Any]]:
        """Optional readout of the optimizer's live refit schedule
        (adaptive step budgets, fit/arrival latencies, deferred-fit
        debt).  Surfaced by the service in ``StatusResponse`` pump
        stats; None when the optimizer has nothing to report."""
        return None

    # ------------------------------------------------------------ helpers
    @property
    def successes(self) -> List[Observation]:
        return [o for o in self.history if not o.failed and o.value is not None]

    def best(self) -> Optional[Observation]:
        succ = self.successes
        return max(succ, key=lambda o: o.value) if succ else None

    # checkpoint/restore of optimizer state (experiment-level fault
    # tolerance: the suggestion service resumes from the observation log)
    def state(self) -> Dict[str, Any]:
        return {"history": [o.to_json() for o in self.history]}

    def restore(self, state: Dict[str, Any]) -> None:
        """Idempotent replay of a checkpointed observation log: only the
        tail beyond what this optimizer has already absorbed is fed to
        ``tell``, so a checkpoint restore followed by a resume replay (or
        two restores of the same log) never double-counts observations."""
        obs = [Observation.from_json(d) for d in state.get("history", [])]
        new = obs[len(self.history):]
        if new:
            self.tell(new)


class StoppingPolicy(abc.ABC):
    """Server-side early-stopping policy over trial metric streams.

    Owned by the suggestion service (not the scheduler): all workers of an
    experiment report into ONE policy instance, so pruning decisions are
    consistent across schedulers and survive restarts via ``state()`` /
    ``restore()`` (JSON-serializable rung snapshot) plus replay of the
    append-only metric log.

    ``report`` answers one of the protocol decisions: ``"continue"``,
    ``"stop"`` (final), or ``"pause"`` (release resources, keep the
    suggestion pending, resume from checkpoint on promotion).  ``version``
    must increase on every state mutation — the service uses it to decide
    when to re-persist the rung snapshot.
    """

    version: int = 0

    @abc.abstractmethod
    def report(self, trial_id: str, step: int, value: float) -> str:
        """Evaluate one progress report -> 'continue' | 'stop' | 'pause'."""

    def next_rung(self, trial_id: str) -> Optional[int]:
        """Smallest step at which this trial's next report matters (None =
        every report is equally (un)interesting)."""
        return None

    @abc.abstractmethod
    def state(self) -> Dict[str, Any]:
        """JSON-serializable snapshot (round-trips through ``restore``)."""

    @abc.abstractmethod
    def restore(self, state: Dict[str, Any]) -> None:
        """Wholesale-replace internal state from a ``state()`` snapshot."""


_REGISTRY: Dict[str, Any] = {}
_STOPPING_REGISTRY: Dict[str, Any] = {}


def register(name: str):
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def register_stopping(name: str):
    def deco(cls):
        _STOPPING_REGISTRY[name] = cls
        return cls
    return deco


def make_optimizer(name: str, space: Space, seed: int = 0,
                   **options) -> Optimizer:
    # import for side-effect registration
    from repro.core.suggest import (bayesopt, evolution, grid, pso,  # noqa
                                    random_search, sobol)
    if name not in _REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {list(_REGISTRY)}")
    return _REGISTRY[name](space, seed=seed, **options)


def make_stopping_policy(options: Dict[str, Any],
                         goal: str = "max") -> StoppingPolicy:
    """Build the experiment's early-stopping policy from its config dict
    (``ExperimentConfig.early_stop``).  ``policy`` selects the registered
    implementation (default ``asha``); the rest are constructor options."""
    from repro.core.suggest import asha  # noqa: side-effect registration
    opts = dict(options or {})
    name = opts.pop("policy", "asha")
    if name not in _STOPPING_REGISTRY:
        raise KeyError(f"unknown stopping policy {name!r}; "
                       f"have {list(_STOPPING_REGISTRY)}")
    return _STOPPING_REGISTRY[name](goal=goal, **opts)
