"""Particle swarm optimization [Blum & Li 2008, cited by the paper].

Asynchronous-friendly: each ask() serves the next particle in round-robin;
tell() matches results back to particles via the assignment echo in
metadata, so parallel workers can evaluate different particles at once.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.space import Assignment, Space
from repro.core.suggest.base import Observation, Optimizer, register


@register("pso")
class ParticleSwarm(Optimizer):
    def __init__(self, space: Space, seed: int = 0, particles: int = 8,
                 inertia: float = 0.7, c_personal: float = 1.4,
                 c_global: float = 1.4):
        super().__init__(space, seed)
        d = len(space)
        self.n = particles
        self.w, self.cp, self.cg = inertia, c_personal, c_global
        self.x = self.rng.uniform(size=(particles, d))
        self.v = self.rng.uniform(-0.1, 0.1, size=(particles, d))
        self.pbest = np.full(particles, -np.inf)
        self.pbest_x = self.x.copy()
        self.gbest = -np.inf
        self.gbest_x = self.x[0].copy()
        self._next = 0

    def ask(self, n: int = 1) -> List[Assignment]:
        out = []
        for _ in range(n):
            i = self._next % self.n
            self._next += 1
            a = self.space.from_unit(self.x[i])
            a["__particle__"] = i      # echo key (stripped by scheduler)
            out.append(a)
        return out

    def _update(self, observations: Sequence[Observation]) -> None:
        for o in observations:
            i = o.metadata.get("__particle__")
            if i is None or o.failed or o.value is None:
                continue
            i = int(i) % self.n
            if o.value > self.pbest[i]:
                self.pbest[i] = o.value
                self.pbest_x[i] = self.space.to_unit(
                    {k: v for k, v in o.assignment.items()
                     if not k.startswith("__")})
            if o.value > self.gbest:
                self.gbest = o.value
                self.gbest_x = self.pbest_x[i].copy()
            r1, r2 = self.rng.uniform(size=2)
            self.v[i] = (self.w * self.v[i]
                         + self.cp * r1 * (self.pbest_x[i] - self.x[i])
                         + self.cg * r2 * (self.gbest_x - self.x[i]))
            self.x[i] = np.clip(self.x[i] + self.v[i], 0.0, 1.0)
