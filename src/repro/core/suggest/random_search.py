"""Random search [Bergstra & Bengio 2012] — the paper's baseline strategy."""
from __future__ import annotations

from typing import List

from repro.core.space import Assignment, Space
from repro.core.suggest.base import Optimizer, register


@register("random")
class RandomSearch(Optimizer):
    def ask(self, n: int = 1) -> List[Assignment]:
        return self.space.sample(self.rng, n)
