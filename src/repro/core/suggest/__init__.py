"""Suggestion service: ask/tell black-box optimizers over a Space.

This is the in-repo replacement for the SigOpt API that Orchestrate called
out to — every strategy the paper cites (grid [3], random [2], evolutionary
[14], swarm [4], Bayesian [6,11]) plus quasi-random Sobol and ASHA early
stopping (paper §2.5 "stopping experiments").
"""
from repro.core.suggest.base import (Observation, Optimizer, StoppingPolicy,
                                     make_optimizer, make_stopping_policy)
from repro.core.suggest.asha import ASHA

__all__ = ["Observation", "Optimizer", "make_optimizer", "ASHA",
           "StoppingPolicy", "make_stopping_policy"]
