"""Regularized evolution [Real et al. 2019-style; the paper cites
evolutionary strategies as a suitable HPO method]."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.space import Assignment, Space
from repro.core.suggest.base import Observation, Optimizer, register


@register("evolution")
class RegularizedEvolution(Optimizer):
    def __init__(self, space: Space, seed: int = 0, population: int = 16,
                 tournament: int = 4, mutate_scale: float = 0.15):
        super().__init__(space, seed)
        self.population_size = population
        self.tournament = tournament
        self.mutate_scale = mutate_scale
        self._population: List[Observation] = []   # FIFO of recent survivors

    def ask(self, n: int = 1) -> List[Assignment]:
        out = []
        for _ in range(n):
            if len(self._population) < self.population_size:
                out.append(self.space.sample(self.rng, 1)[0])
                continue
            idx = self.rng.choice(len(self._population),
                                  size=min(self.tournament,
                                           len(self._population)),
                                  replace=False)
            parent = max((self._population[i] for i in idx),
                         key=lambda o: o.value)
            out.append(self._mutate(parent.assignment))
        return out

    def _mutate(self, a: Assignment) -> Assignment:
        u = self.space.to_unit(a)
        i = self.rng.integers(len(u))
        p = self.space.params[i]
        if p.kind == "categorical":
            u[i] = self.rng.uniform()
        else:
            u[i] = np.clip(u[i] + self.rng.normal(0, self.mutate_scale), 0, 1)
        return self.space.from_unit(u)

    def _update(self, observations: Sequence[Observation]) -> None:
        for o in observations:
            if o.failed or o.value is None:
                continue
            self._population.append(o)
            if len(self._population) > self.population_size:
                self._population.pop(0)            # age-based removal
