"""Grid search — enumerates a lattice once, then refines with jittered
resampling when the budget exceeds the lattice size."""
from __future__ import annotations

from typing import List

from repro.core.space import Assignment, Space
from repro.core.suggest.base import Optimizer, register


@register("grid")
class GridSearch(Optimizer):
    def __init__(self, space: Space, seed: int = 0, points_per_dim: int = 5):
        super().__init__(space, seed)
        self._queue = space.grid(points_per_dim)
        self.rng.shuffle(self._queue)  # decorrelate parallel workers

    def ask(self, n: int = 1) -> List[Assignment]:
        out = []
        for _ in range(n):
            if self._queue:
                out.append(self._queue.pop())
            else:                       # budget > lattice: jittered resample
                out.append(self.space.sample(self.rng, 1)[0])
        return out
