"""Experiment definitions — the unit the CLI verbs operate on (paper §3.5).

An ``ExperimentConfig`` is what the user's experiment YAML deserializes into:
the search space, metric/goal, observation budget, parallel bandwidth
(paper: "how many of those evaluations may be run in parallel"), resource
requirements per trial (paper §3.5.1: "number of GPUs needed per model"),
and the optimizer choice.  A ``TrialSpec`` is the hermetic work unit — the
TPU-native stand-in for the paper's Docker container (see DESIGN.md §2).
"""
from __future__ import annotations

import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core.space import Space

STATUS_PENDING = "pending"
STATUS_RUNNING = "running"
STATUS_COMPLETE = "complete"
STATUS_FAILED = "failed"
STATUS_DELETED = "deleted"


@dataclass
class Resources:
    """Per-trial resource request (paper §3.5.1)."""
    pool: str = "cpu"          # which cluster pool (heterogeneous, §2.3)
    chips: int = 1             # slice size within the pool

    def to_json(self):
        return {"pool": self.pool, "chips": self.chips}

    @classmethod
    def from_json(cls, d):
        return cls(d.get("pool", "cpu"), int(d.get("chips", 1)))


@dataclass
class ExperimentConfig:
    name: str
    space: Space
    metric: str = "objective"
    goal: str = "max"                      # max | min
    budget: int = 20                       # observation budget
    parallel: int = 4                      # parallel bandwidth
    optimizer: str = "gp"
    optimizer_options: Dict[str, Any] = field(default_factory=dict)
    resources: Resources = field(default_factory=Resources)
    executor: str = "host"                 # host | slice | vmap
    max_retries: int = 1
    straggler_factor: float = 0.0          # 0 disables speculation
    early_stop: Optional[Dict[str, Any]] = None   # StoppingPolicy options
    report_every: int = 1                  # min step delta between service
                                           # reports (rung crossings always
                                           # go through — see Scheduler)
    prefetch: Optional[int] = None         # suggestion-pipeline queue depth
                                           # (None = auto: pump on for
                                           # model-based optimizers only;
                                           # 0 = fully synchronous)
    staleness: int = 8                     # K: prefetched suggestions are
                                           # invalidated after K new
                                           # observations
    entrypoint: Optional[str] = None       # "module:function" for CLI runs
    seed: int = 0

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name, "space": self.space.to_config(),
            "metric": self.metric, "goal": self.goal, "budget": self.budget,
            "parallel": self.parallel, "optimizer": self.optimizer,
            "optimizer_options": self.optimizer_options,
            "resources": self.resources.to_json(), "executor": self.executor,
            "max_retries": self.max_retries,
            "straggler_factor": self.straggler_factor,
            "early_stop": self.early_stop,
            "report_every": self.report_every,
            "prefetch": self.prefetch,
            "staleness": self.staleness,
            "entrypoint": self.entrypoint,
            "seed": self.seed,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ExperimentConfig":
        return cls(
            name=d["name"], space=Space.from_config(d["space"]),
            metric=d.get("metric", "objective"), goal=d.get("goal", "max"),
            budget=int(d.get("budget", 20)),
            parallel=int(d.get("parallel", 4)),
            optimizer=d.get("optimizer", "gp"),
            optimizer_options=d.get("optimizer_options", {}),
            resources=Resources.from_json(d.get("resources", {})),
            executor=d.get("executor", "host"),
            max_retries=int(d.get("max_retries", 1)),
            straggler_factor=float(d.get("straggler_factor", 0.0)),
            early_stop=d.get("early_stop"),
            report_every=int(d.get("report_every", 1)),
            prefetch=(None if d.get("prefetch") is None
                      else int(d["prefetch"])),
            staleness=int(d.get("staleness", 8)),
            entrypoint=d.get("entrypoint"), seed=int(d.get("seed", 0)))


def new_experiment_id() -> str:
    return time.strftime("%Y%m%d-%H%M%S-") + uuid.uuid4().hex[:6]


@dataclass
class TrialSpec:
    """Hermetic trial: pure fn(assignment, ctx) -> float (see DESIGN.md —
    Docker-in-Docker limitation becomes 'trial fns must be self-contained')."""
    trial_id: str
    assignment: Dict[str, Any]
    attempt: int = 0
    speculative: bool = False
    suggestion_id: str = ""    # pending-suggestion handle at the service
    pauses: int = 0            # times the service paused this trial
    paused_obs: int = -1       # experiment-wide observation count at the
                               # last pause (-1 = never paused); the
                               # scheduler resumes a paused trial only
                               # after this grows (new rung information)
