"""Parallel trial scheduler — the Kubernetes-job-controller analogue.

Responsibilities (paper mapping):
* keep ``parallel`` trials in flight against the suggestion service (§2.1:
  "evaluating multiple model configurations simultaneously");
* admission control against the cluster allocator (§3.5.1: Kubernetes
  "manages resource and capacity limitations" -> our allocator does);
* failed observations are first-class results, with bounded retries
  (§2.5: "code throwing exceptions ... report failure");
* ASHA early stopping via ``ctx.report`` (§2.5 stopping experiments);
* straggler mitigation: speculative duplicate of the slowest running trial
  when it exceeds ``straggler_factor x`` the median completed runtime and a
  slot is free — first finisher wins (beyond-paper, required at 1000-node
  scale);
* preemption/revocation: a revoked lease requeues the trial; trials resume
  from their checkpoint directory if they wrote one.

Trials run on a thread pool: jax releases the GIL during compute, and on
real TPU slices each trial drives its own device set.  The scheduler is the
single writer of the experiment store.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
import uuid
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.cluster import Cluster, SliceLease
from repro.core.experiment import ExperimentConfig, TrialSpec
from repro.core.store import Store
from repro.core.suggest import ASHA, Observation
from repro.core.suggest.base import Optimizer


class TrialStopped(Exception):
    """Raised inside a trial when ASHA (or delete) says stop.  Carries the
    last reported (step, value) so the pruned trial still yields a (partial)
    observation — ASHA rung values are informative, not failures."""

    def __init__(self, trial_id, step=None, value=None):
        super().__init__(trial_id)
        self.step, self.value = step, value


class TrialPreempted(Exception):
    """Raised when the trial's slice was revoked mid-run."""


@dataclass
class TrialContext:
    """Handed to the user's trial function (the 'container environment')."""
    trial_id: str
    experiment_id: str
    lease: Optional[SliceLease]
    checkpoint_dir: str
    _log: Callable[[str], None]
    _report: Callable[[int, float], str]
    _should_stop: Callable[[], bool]

    def log(self, msg: str) -> None:
        self._log(msg)

    def report(self, step: int, value: float) -> None:
        """Progress report; raises to stop the trial (ASHA / delete /
        speculative loser / preemption)."""
        if self.lease is not None and self.lease.revoked:
            raise TrialPreempted(self.trial_id)
        if self._should_stop():
            raise TrialStopped(self.trial_id, step, value)
        if self._report(step, value) == "stop":
            raise TrialStopped(self.trial_id, step, value)


@dataclass
class _Running:
    spec: TrialSpec
    future: Future
    lease: Optional[SliceLease]
    started: float
    stop_flag: threading.Event
    speculative_of: Optional[str] = None


class Scheduler:
    def __init__(self, exp_id: str, cfg: ExperimentConfig,
                 optimizer: Optimizer, cluster: Optional[Cluster],
                 store: Store, trial_fn: Callable[[Dict[str, Any],
                                                   TrialContext], float]):
        self.exp_id = exp_id
        self.cfg = cfg
        self.optimizer = optimizer
        self.cluster = cluster
        self.store = store
        self.trial_fn = trial_fn
        self.asha = ASHA(goal=cfg.goal, **cfg.early_stop) \
            if cfg.early_stop else None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._running: Dict[str, _Running] = {}
        self._requeue: List[TrialSpec] = []
        self._done_values: List[float] = []     # runtimes of completions
        self._observations = 0
        self._failures = 0
        self._trial_seq = 0

    # ----------------------------------------------------------------- api
    def stop(self) -> None:
        """Terminate all executions (paper §2.5 / `delete` verb)."""
        self._stop.set()
        for r in list(self._running.values()):
            r.stop_flag.set()

    def run(self) -> Dict[str, Any]:
        self.store.update_status(self.exp_id, state="running",
                                 budget=self.cfg.budget)
        pool = ThreadPoolExecutor(max_workers=self.cfg.parallel + 2,
                                  thread_name_prefix=f"trial-{self.exp_id}")
        try:
            while (self._observations < self.cfg.budget
                   and not self._stop.is_set()):
                self._fill_slots(pool)
                self._maybe_speculate(pool)
                self._harvest()
                time.sleep(0.005)
        finally:
            self.stop()
            # drain
            futures = [r.future for r in self._running.values()]
            if futures:
                wait(futures, timeout=30)
            self._harvest(final=True)
            pool.shutdown(wait=False, cancel_futures=True)
        best = self.optimizer.best()
        status = self.store.update_status(
            self.exp_id,
            state="complete" if not self._stop.is_set() or
            self._observations >= self.cfg.budget else "stopped",
            observations=self._observations, failures=self._failures,
            best=(best.to_json() if best else None))
        return status

    # ------------------------------------------------------------ internals
    def _next_specs(self, n: int) -> List[TrialSpec]:
        specs = []
        while self._requeue and len(specs) < n:
            specs.append(self._requeue.pop(0))
        if len(specs) < n:
            for a in self.optimizer.ask(n - len(specs)):
                self._trial_seq += 1
                specs.append(TrialSpec(f"t{self._trial_seq:04d}", a))
        return specs

    def _in_flight(self) -> int:
        return len(self._running)

    def _pending_budget(self) -> int:
        return self.cfg.budget - self._observations - sum(
            1 for r in self._running.values() if not r.speculative_of)

    def _fill_slots(self, pool: ThreadPoolExecutor) -> None:
        free = self.cfg.parallel - self._in_flight()
        want = min(free, max(0, self._pending_budget()))
        if want <= 0:
            return
        for spec in self._next_specs(want):
            self._launch(pool, spec)

    def _launch(self, pool: ThreadPoolExecutor, spec: TrialSpec,
                speculative_of: Optional[str] = None) -> bool:
        lease = None
        if self.cluster is not None:
            lease = self.cluster.allocate(
                self.cfg.resources.pool, self.cfg.resources.chips,
                on_revoke=lambda l, tid=spec.trial_id: self._on_revoke(tid))
            if lease is None:       # admission control: no capacity
                self._requeue.insert(0, spec)
                return False
        stop_flag = threading.Event()
        run_id = spec.trial_id + (f"-spec{spec.attempt}" if speculative_of
                                  else (f"-r{spec.attempt}" if spec.attempt
                                        else ""))
        ctx = TrialContext(
            trial_id=run_id, experiment_id=self.exp_id, lease=lease,
            checkpoint_dir=str(self.store.exp_dir(self.exp_id)
                               / "ckpt" / spec.trial_id),
            _log=lambda m, rid=run_id: self.store.append_log(
                self.exp_id, rid, m),
            _report=(lambda step, v, tid=spec.trial_id:
                     self.asha.report(tid, step, v) if self.asha
                     else "continue"),
            _should_stop=stop_flag.is_set)
        fut = pool.submit(self._run_trial, spec, ctx)
        self._running[run_id] = _Running(spec, fut, lease, time.time(),
                                         stop_flag, speculative_of)
        return True

    def _run_trial(self, spec: TrialSpec, ctx: TrialContext):
        ctx.log(f"start attempt={spec.attempt} "
                f"assignment={ {k: v for k, v in spec.assignment.items() if not k.startswith('__')} }")
        clean = {k: v for k, v in spec.assignment.items()
                 if not k.startswith("__")}
        value = self.trial_fn(clean, ctx)
        ctx.log(f"done value={value}")
        return value

    def _on_revoke(self, trial_id: str) -> None:
        # lease revoked (node failure): flag the trial; harvest requeues it
        for rid, r in self._running.items():
            if r.spec.trial_id == trial_id:
                r.stop_flag.set()

    def _median_runtime(self) -> Optional[float]:
        if len(self._done_values) < 3:
            return None
        s = sorted(self._done_values)
        return s[len(s) // 2]

    def _maybe_speculate(self, pool: ThreadPoolExecutor) -> None:
        if not self.cfg.straggler_factor or self._stop.is_set():
            return
        med = self._median_runtime()
        if med is None or self._in_flight() >= self.cfg.parallel:
            return
        now = time.time()
        for rid, r in list(self._running.items()):
            if r.speculative_of or r.spec.speculative:
                continue
            already = any(rr.speculative_of == r.spec.trial_id
                          for rr in self._running.values())
            if already:
                continue
            if now - r.started > self.cfg.straggler_factor * med:
                dup = TrialSpec(r.spec.trial_id, r.spec.assignment,
                                attempt=r.spec.attempt + 1, speculative=True)
                if self._launch(pool, dup, speculative_of=r.spec.trial_id):
                    self.store.append_log(
                        self.exp_id, rid,
                        f"straggler: speculative duplicate launched "
                        f"(elapsed {now - r.started:.1f}s > "
                        f"{self.cfg.straggler_factor:.1f} x median {med:.1f}s)")

    def _harvest(self, final: bool = False) -> None:
        done = [(rid, r) for rid, r in self._running.items()
                if r.future.done()]
        for rid, r in done:
            del self._running[rid]
            if r.lease is not None and self.cluster is not None:
                self.cluster.release(r.lease)
            stopped_at = None
            try:
                value = r.future.result()
                err = None
            except (TrialStopped,) as e:
                value, err = e.value, ("stopped", str(e))
                stopped_at = e.step
            except TrialPreempted as e:
                value, err = None, ("preempted", str(e))
            except Exception as e:  # noqa: trial crash is data, not a bug
                value, err = None, ("crashed",
                                    f"{type(e).__name__}: {e}")
                self.store.append_log(self.exp_id, rid,
                                      "TRACEBACK\n" + traceback.format_exc())

            origin = r.speculative_of or r.spec.trial_id
            winner_done = any(o.metadata.get("trial_id") == origin
                              for o in self.optimizer.history
                              if o.metadata)
            if winner_done:
                continue    # a speculative twin already reported

            if err is None:
                # cancel the twin, if any
                for rr in self._running.values():
                    if (rr.speculative_of == origin
                            or rr.spec.trial_id == origin):
                        rr.stop_flag.set()
                runtime = time.time() - r.started
                self._done_values.append(runtime)
                goal_v = value if self.cfg.goal == "max" else -value
                obs = Observation(
                    r.spec.assignment, goal_v,
                    metadata={"trial_id": origin, "runtime_s": runtime,
                              "attempt": r.spec.attempt,
                              **{k: v for k, v in r.spec.assignment.items()
                                 if k.startswith("__")}})
                self.optimizer.tell([obs])
                self.store.append_observation(self.exp_id, obs, origin)
                self._observations += 1
            elif err[0] == "stopped" and value is not None:
                # early-stopped: record the last rung value as a pruned
                # (partial) observation — informative, not a failure
                goal_v = value if self.cfg.goal == "max" else -value
                obs = Observation(r.spec.assignment, goal_v,
                                  metadata={"trial_id": origin,
                                            "pruned": True,
                                            "pruned_at_step": stopped_at})
                self.optimizer.tell([obs])
                self.store.append_observation(self.exp_id, obs, origin)
                self._observations += 1
            elif err[0] == "stopped":
                # stopped before any report (delete/shutdown): drop silently
                pass
            elif err[0] == "preempted" or (err[0] == "crashed"
                                           and r.spec.attempt
                                           < self.cfg.max_retries):
                if not final and not self._stop.is_set():
                    self._requeue.append(TrialSpec(
                        r.spec.trial_id, r.spec.assignment,
                        attempt=r.spec.attempt + 1))
                    self.store.append_log(self.exp_id, rid,
                                          f"requeued after {err[0]}")
            else:
                obs = Observation(r.spec.assignment, None, failed=True,
                                  metadata={"trial_id": origin,
                                            "reason": err[1]})
                self.optimizer.tell([obs])
                self.store.append_observation(self.exp_id, obs, origin)
                self._observations += 1
                self._failures += 1
            self.store.update_status(
                self.exp_id, observations=self._observations,
                failures=self._failures, running=self._in_flight())
