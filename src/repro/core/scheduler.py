"""Parallel trial scheduler — the Kubernetes-job-controller analogue.

Responsibilities (paper mapping):
* keep ``parallel`` trials in flight against the suggestion service (§2.1:
  "evaluating multiple model configurations simultaneously");
* admission control against the cluster allocator (§3.5.1: Kubernetes
  "manages resource and capacity limitations" -> our allocator does);
* failed observations are first-class results, with bounded retries
  (§2.5: "code throwing exceptions ... report failure");
* early stopping via ``ctx.report`` (§2.5 stopping experiments) — the
  decision is made SERVICE-side (shared ASHA rung table behind
  ``SuggestionClient.report``), so any number of schedulers driving one
  experiment prune consistently; this scheduler only honors the decision:
  ``stop`` prunes the trial, ``pause`` checkpoints its progress marker,
  releases the lease, and requeues the spec for a later resume (promotion);
* straggler mitigation: speculative duplicate of the slowest running trial
  when it exceeds ``straggler_factor x`` the median completed runtime and a
  slot is free — first finisher wins (beyond-paper, required at 1000-node
  scale);
* preemption/revocation: a revoked lease requeues the trial; trials resume
  from their checkpoint directory if they wrote one.

Trials run on a thread pool: jax releases the GIL during compute, and on
real TPU slices each trial drives its own device set.  The scheduler never
holds a raw ``Optimizer``: it drives a ``SuggestionClient`` (suggest /
observe / release — see API.md), so the same loop runs against the
in-process ``LocalClient`` or a remote HTTP suggestion service.  The
service is the single writer of the observation log; the scheduler writes
only trial logs and its local status mirror.
"""
from __future__ import annotations

import json
import queue
import threading
import time
import traceback
import uuid
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.api.client import SuggestionClient
from repro.api.protocol import (ApiError, DECISION_CONTINUE, DECISION_PAUSE,
                                DECISION_STOP, ObserveRequest, ReportRequest)
from repro.core.cluster import Cluster, SliceLease
from repro.core.experiment import ExperimentConfig, TrialSpec
from repro.core.space import strip_internal
from repro.core.store import Store


class TrialExit(Exception):
    """Base for control-flow exits raised from ``ctx.report``; carries the
    last reported (step, value) so harvest can record the partial curve."""

    def __init__(self, trial_id, step=None, value=None):
        super().__init__(trial_id)
        self.step, self.value = step, value


class TrialStopped(TrialExit):
    """Raised inside a trial when the service (or delete) says stop.
    The pruned trial still yields a (partial) observation — rung values
    are informative, not failures."""


class TrialPaused(TrialExit):
    """Raised inside a trial when the service answers ``pause``: the trial
    winds down, its lease is released and its spec requeued; it resumes
    later from its checkpoint (promotion-based early stopping)."""


class TrialPreempted(Exception):
    """Raised when the trial's slice was revoked mid-run."""


@dataclass
class TrialContext:
    """Handed to the user's trial function (the 'container environment')."""
    trial_id: str
    experiment_id: str
    lease: Optional[SliceLease]
    checkpoint_dir: str
    _log: Callable[[str], None]
    _report: Callable[[int, float], str]
    _should_stop: Callable[[], bool]
    resume_step: Optional[int] = None   # set when resuming a paused trial:
                                        # the step it last reported (your
                                        # checkpoint in checkpoint_dir is
                                        # at or beyond this step)

    def log(self, msg: str) -> None:
        self._log(msg)

    def report(self, step: int, value: float) -> None:
        """Progress report — a thin client call to the suggestion
        service's trial-events endpoint.  Raises to end this execution:
        ``TrialStopped`` on a final prune (service decision / delete /
        speculative loser), ``TrialPaused`` when the service parks the
        trial pending promotion, ``TrialPreempted`` on lease revocation.
        Save your checkpoint (to ``checkpoint_dir``) before or at each
        report so pause/preemption can resume without losing work."""
        if self.lease is not None and self.lease.revoked:
            raise TrialPreempted(self.trial_id)
        if self._should_stop():
            raise TrialStopped(self.trial_id, step, value)
        decision = self._report(step, value)
        if decision == DECISION_STOP:
            raise TrialStopped(self.trial_id, step, value)
        if decision == DECISION_PAUSE:
            raise TrialPaused(self.trial_id, step, value)


@dataclass
class _Running:
    spec: TrialSpec
    future: Future
    lease: Optional[SliceLease]
    started: float
    stop_flag: threading.Event
    speculative_of: Optional[str] = None


class _Reporter:
    """Worker-side report batching: at most one service round trip per
    ``cfg.report_every`` steps per trial (same-step repeats always
    coalesce), so a tight training loop can't DoS the service — but a
    rung boundary is never skipped: the service returns ``next_rung`` and
    any report at/past it goes through regardless of the throttle."""

    def __init__(self, sched: "Scheduler", spec: TrialSpec):
        self._sched = sched
        self._spec = spec
        self._last_step: Optional[int] = None
        self._next_rung: Optional[int] = None

    def __call__(self, step: int, value: float) -> str:
        every = max(1, self._sched.cfg.report_every)
        if self._last_step is not None:
            rung_due = (self._next_rung is not None
                        and step >= self._next_rung)
            if step - self._last_step < every and not rung_due:
                return DECISION_CONTINUE        # coalesced locally
        try:
            d = self._sched.client.report(ReportRequest(
                exp_id=self._sched.exp_id, trial_id=self._spec.trial_id,
                step=step, value=value,
                suggestion_id=self._spec.suggestion_id))
        except ApiError:
            # progress metadata is advisory: a service blip must not kill
            # the trial — skip this report and keep training
            return DECISION_CONTINUE
        self._last_step = step
        self._next_rung = d.next_rung
        return d.decision


class Scheduler:
    def __init__(self, exp_id: str, cfg: ExperimentConfig,
                 client: SuggestionClient, cluster: Optional[Cluster],
                 store: Store, trial_fn: Callable[[Dict[str, Any],
                                                   TrialContext], float]):
        self.exp_id = exp_id
        self.cfg = cfg
        self.client = client
        self.cluster = cluster
        self.store = store
        self.trial_fn = trial_fn
        self._stop = threading.Event()
        self._wake = threading.Event()          # set by future done-callbacks
        self._lock = threading.Lock()
        self._status_interval = 0.2             # min seconds between mirrors
        self._last_status_write = 0.0
        self._running: Dict[str, _Running] = {}
        self._requeue: List[TrialSpec] = []
        self._done_values: List[float] = []     # runtimes of completions
        self._reported: set = set()             # origins already observed
        self._suggest_retry_at = 0.0            # backoff after empty batch
        self._observations = 0
        self._failures = 0
        self._trial_seq = 0

    # ----------------------------------------------------------------- api
    @property
    def running_trials(self) -> int:
        return len(self._running)

    @property
    def paused_trials(self) -> int:
        """Trials parked by a service ``pause`` decision, awaiting
        promotion (their suggestions stay pending at the service)."""
        return sum(1 for s in self._requeue if s.paused_obs >= 0)

    @property
    def finished(self) -> bool:
        return self._stop.is_set() or self._observations >= self.cfg.budget

    def stop(self) -> None:
        """Terminate all executions (paper §2.5 / `delete` verb)."""
        self._stop.set()
        self._wake.set()
        for r in list(self._running.values()):
            r.stop_flag.set()

    def run(self) -> Dict[str, Any]:
        # resume lands mid-budget: the service knows how far the log got
        for attempt in range(3):
            try:
                st = self.client.status(self.exp_id)
                break
            except ApiError as e:
                if attempt == 2:
                    # surface the failure instead of dying silently in a
                    # background thread
                    self.store.update_status(self.exp_id, state="failed",
                                             error=str(e))
                    raise
                time.sleep(0.2 * (attempt + 1))
        self._observations = st.observations
        self._failures = st.failures
        self.store.update_status(self.exp_id, state="running",
                                 budget=self.cfg.budget)
        pool = ThreadPoolExecutor(max_workers=self.cfg.parallel + 2,
                                  thread_name_prefix=f"trial-{self.exp_id}")
        try:
            idle = 0
            while (self._observations < self.cfg.budget
                   and not self._stop.is_set()):
                # event-driven tick: trial completions wake the loop via
                # future done-callbacks; the timeout only paces straggler
                # checks, suggest backoff retries, and idle re-sync.
                # Harvest BEFORE filling so a completion frees its slot in
                # the same tick (fill-first would idle a slot for a full
                # wait timeout after every completion).
                self._wake.clear()
                self._harvest()
                self._fill_slots(pool)
                self._maybe_speculate(pool)
                self._prefetch_ahead()
                if not self._running and not self._requeue:
                    # other workers may hold the remaining budget, or the
                    # experiment may have been stopped service-side: re-sync
                    idle += 1
                    if idle % 2 == 0:
                        st = None
                        try:
                            st = self.client.status(self.exp_id)
                        except ApiError:
                            pass        # service blip; keep waiting
                        if st is not None:
                            self._observations = max(self._observations,
                                                     st.observations)
                            self._failures = max(self._failures, st.failures)
                            if st.state in ("stopped", "deleted"):
                                self._stop.set()
                else:
                    idle = 0
                if (self._observations >= self.cfg.budget
                        or self._stop.is_set()):
                    break       # don't sleep a tick just to re-test the loop
                self._wake.wait(0.05)
        finally:
            self.stop()
            # drain
            futures = [r.future for r in self._running.values()]
            if futures:
                wait(futures, timeout=30)
            self._harvest(final=True)
            # locally-requeued specs still hold pending budget — return it
            for spec in self._requeue:
                self._release(spec)
            self._requeue.clear()
            pool.shutdown(wait=False, cancel_futures=True)
        try:
            best = self.client.best(self.exp_id)
        except ApiError:
            best = None     # final readout is cosmetic; don't lose the run
        status = self.store.update_status(
            self.exp_id,
            state="complete" if not self._stop.is_set() or
            self._observations >= self.cfg.budget else "stopped",
            observations=self._observations, failures=self._failures,
            running=self._in_flight(),   # pool is drained: normally 0
            best=(best.to_json() if best else None))
        return status

    # ------------------------------------------------------------ internals
    def _pause_marker(self, trial_id: str):
        return (self.store.exp_dir(self.exp_id) / "ckpt" / trial_id
                / "pause.json")

    def _write_pause_marker(self, spec: TrialSpec, step, value) -> None:
        """Snapshot the paused trial's progress next to its checkpoints so
        the resumed attempt knows where to pick up (``ctx.resume_step``)."""
        p = self._pause_marker(spec.trial_id)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"step": step, "value": value,
                                 "pauses": spec.pauses + 1,
                                 "time": time.time()}))

    def _load_pause_marker(self, ckpt_dir) -> Optional[int]:
        try:
            return int(json.loads(
                (ckpt_dir / "pause.json").read_text())["step"])
        except (OSError, ValueError, KeyError):
            return None

    def _next_specs(self, n: int) -> List[TrialSpec]:
        specs: List[TrialSpec] = []
        deferred: List[TrialSpec] = []
        while self._requeue and len(specs) < n:
            spec = self._requeue.pop(0)
            if spec.paused_obs >= 0 and self._observations <= spec.paused_obs:
                # paused awaiting promotion: no new rung information has
                # arrived since the pause, so resuming now would only be
                # re-paused — prefer fresh work
                deferred.append(spec)
                continue
            specs.append(spec)
        if len(specs) < n and time.time() >= self._suggest_retry_at:
            try:
                batch = self.client.suggest(self.exp_id, n - len(specs))
            except ApiError:
                # transient service failure: back off, retry next tick
                self._suggest_retry_at = time.time() + 0.5
                return specs
            if not batch.suggestions:
                # budget held by pending suggestions elsewhere — back off
                self._suggest_retry_at = time.time() + 0.05
            for s in batch.suggestions:
                self._trial_seq += 1
                specs.append(TrialSpec(f"t{self._trial_seq:04d}",
                                       s.assignment,
                                       suggestion_id=s.suggestion_id))
        if not specs and deferred and not self._running:
            # nothing else to run and no trial in flight that could bring
            # new information: resume paused trials anyway rather than
            # deadlock (their next pause with unchanged observations is
            # finalized as a pruned observation — see _harvest)
            specs, deferred = deferred[:n], deferred[n:]
        self._requeue.extend(deferred)
        return specs

    def _in_flight(self) -> int:
        return len(self._running)

    def _pending_budget(self) -> int:
        return self.cfg.budget - self._observations - sum(
            1 for r in self._running.values() if not r.speculative_of)

    def _fill_slots(self, pool: ThreadPoolExecutor) -> None:
        free = self.cfg.parallel - self._in_flight()
        want = min(free, max(0, self._pending_budget()))
        if want <= 0:
            return
        for spec in self._next_specs(want):
            self._launch(pool, spec)

    def _prefetch_ahead(self) -> None:
        """Pipelined next-suggestion fetch (opt-in via ``cfg.prefetch``):
        while every slot is busy, pull ONE spec ahead of need into the
        local requeue so the next freed slot launches immediately instead
        of paying a service round trip first.  The spec's suggestion stays
        pending service-side; shutdown releases it like any requeued spec."""
        if not self.cfg.prefetch or self._stop.is_set():
            return
        if self._requeue or self._in_flight() < self.cfg.parallel:
            return
        if self._pending_budget() <= 0 \
                or time.time() < self._suggest_retry_at:
            return
        try:
            batch = self.client.suggest(self.exp_id, 1)
        except ApiError:
            self._suggest_retry_at = time.time() + 0.5
            return
        if not batch.suggestions:
            self._suggest_retry_at = time.time() + 0.05
        for s in batch.suggestions:
            self._trial_seq += 1
            self._requeue.append(TrialSpec(f"t{self._trial_seq:04d}",
                                           s.assignment,
                                           suggestion_id=s.suggestion_id))

    def _launch(self, pool: ThreadPoolExecutor, spec: TrialSpec,
                speculative_of: Optional[str] = None) -> bool:
        lease = None
        if self.cluster is not None:
            lease = self.cluster.allocate(
                self.cfg.resources.pool, self.cfg.resources.chips,
                on_revoke=lambda l, tid=spec.trial_id: self._on_revoke(tid))
            if lease is None:       # admission control: no capacity
                self._requeue.insert(0, spec)
                return False
        stop_flag = threading.Event()
        if speculative_of:
            suffix = f"-spec{spec.attempt}"
        else:
            suffix = ((f"-r{spec.attempt}" if spec.attempt else "")
                      + (f"-p{spec.pauses}" if spec.pauses else ""))
        run_id = spec.trial_id + suffix
        ckpt_dir = self.store.exp_dir(self.exp_id) / "ckpt" / spec.trial_id
        ctx = TrialContext(
            trial_id=run_id, experiment_id=self.exp_id, lease=lease,
            checkpoint_dir=str(ckpt_dir),
            _log=lambda m, rid=run_id: self.store.append_log(
                self.exp_id, rid, m),
            _report=_Reporter(self, spec),
            _should_stop=stop_flag.is_set,
            resume_step=self._load_pause_marker(ckpt_dir)
            if spec.pauses else None)
        fut = pool.submit(self._run_trial, spec, ctx)
        fut.add_done_callback(lambda _f: self._wake.set())
        self._running[run_id] = _Running(spec, fut, lease, time.time(),
                                         stop_flag, speculative_of)
        return True

    def _run_trial(self, spec: TrialSpec, ctx: TrialContext):
        clean = strip_internal(spec.assignment)
        ctx.log(f"start attempt={spec.attempt} assignment={clean}")
        value = self.trial_fn(clean, ctx)
        ctx.log(f"done value={value}")
        return value

    def _on_revoke(self, trial_id: str) -> None:
        # lease revoked (node failure): flag the trial; harvest requeues it
        for rid, r in self._running.items():
            if r.spec.trial_id == trial_id:
                r.stop_flag.set()

    def _median_runtime(self) -> Optional[float]:
        if len(self._done_values) < 3:
            return None
        s = sorted(self._done_values)
        return s[len(s) // 2]

    def _maybe_speculate(self, pool: ThreadPoolExecutor) -> None:
        if not self.cfg.straggler_factor or self._stop.is_set():
            return
        med = self._median_runtime()
        if med is None or self._in_flight() >= self.cfg.parallel:
            return
        now = time.time()
        for rid, r in list(self._running.items()):
            if r.speculative_of or r.spec.speculative:
                continue
            already = any(rr.speculative_of == r.spec.trial_id
                          for rr in self._running.values())
            if already:
                continue
            if now - r.started > self.cfg.straggler_factor * med:
                dup = TrialSpec(r.spec.trial_id, r.spec.assignment,
                                attempt=r.spec.attempt + 1, speculative=True,
                                suggestion_id=r.spec.suggestion_id)
                if self._launch(pool, dup, speculative_of=r.spec.trial_id):
                    self.store.append_log(
                        self.exp_id, rid,
                        f"straggler: speculative duplicate launched "
                        f"(elapsed {now - r.started:.1f}s > "
                        f"{self.cfg.straggler_factor:.1f} x median {med:.1f}s)")

    def _goal_value(self, value: float) -> float:
        """Observed values are goal-normalized (maximize) before they
        reach the service."""
        return value if self.cfg.goal == "max" else -value

    def _observe(self, spec: TrialSpec, origin: str,
                 value: Optional[float], failed: bool = False,
                 metadata: Optional[Dict[str, Any]] = None) -> None:
        """Report one trial outcome through the suggestion service.  The
        service deduplicates by suggestion_id (first observe wins), so a
        speculative twin racing us is counted at most once.  Transient
        service failures are retried; a lost observe must not abort the
        whole run (the service reclaims the pending entry on restart)."""
        req = ObserveRequest(
            exp_id=self.exp_id, suggestion_id=spec.suggestion_id,
            assignment=spec.assignment, value=value, failed=failed,
            trial_id=origin, metadata=metadata or {})
        resp = None
        for attempt in range(3):
            try:
                resp = self.client.observe(req)
                break
            except ApiError as e:
                if attempt == 2:
                    self.store.append_log(
                        self.exp_id, origin,
                        f"observe lost after 3 attempts: {e}")
                    # hand the budget slot back so the run can still
                    # finish (the computed value is lost, a fresh
                    # suggestion replaces it)
                    self._release(spec)
                else:
                    time.sleep(0.1 * (attempt + 1))
        self._reported.add(origin)
        if resp is None or not resp.accepted:
            return
        self._observations = max(self._observations + 1, resp.observations)
        if failed:
            self._failures += 1

    def _release(self, spec: TrialSpec) -> None:
        if not spec.suggestion_id:
            return
        try:
            self.client.release(self.exp_id, spec.suggestion_id)
        except ApiError:
            pass    # experiment already stopped/deleted service-side

    def _write_status(self, force: bool = False) -> None:
        """Mirror progress into status.json at most once per harvest pass
        and no more often than ``_status_interval`` (the run-final write is
        forced, so the mirror always converges)."""
        now = time.monotonic()
        if not force and now - self._last_status_write < self._status_interval:
            return
        self._last_status_write = now
        self.store.update_status(
            self.exp_id, observations=self._observations,
            failures=self._failures, running=self._in_flight())

    def _harvest(self, final: bool = False) -> None:
        done = [(rid, r) for rid, r in self._running.items()
                if r.future.done()]
        for rid, r in done:
            del self._running[rid]
            if r.lease is not None and self.cluster is not None:
                self.cluster.release(r.lease)
            stopped_at = None
            try:
                value = r.future.result()
                err = None
            except (TrialStopped,) as e:
                value, err = e.value, ("stopped", str(e))
                stopped_at = e.step
            except TrialPaused as e:
                value, err = e.value, ("paused", str(e))
                stopped_at = e.step
            except TrialPreempted as e:
                value, err = None, ("preempted", str(e))
            except Exception as e:  # noqa: trial crash is data, not a bug
                value, err = None, ("crashed",
                                    f"{type(e).__name__}: {e}")
                self.store.append_log(self.exp_id, rid,
                                      "TRACEBACK\n" + traceback.format_exc())

            origin = r.speculative_of or r.spec.trial_id
            if origin in self._reported:
                continue    # a speculative twin already reported

            if err is None:
                # cancel the twin, if any
                for rr in self._running.values():
                    if (rr.speculative_of == origin
                            or rr.spec.trial_id == origin):
                        rr.stop_flag.set()
                runtime = time.time() - r.started
                self._done_values.append(runtime)
                goal_v = self._goal_value(value)
                self._observe(r.spec, origin, goal_v, metadata={
                    "trial_id": origin, "runtime_s": runtime,
                    "attempt": r.spec.attempt,
                    **{k: v for k, v in r.spec.assignment.items()
                       if k.startswith("__")}})
            elif err[0] == "paused":
                progressed = (r.spec.paused_obs < 0
                              or self._observations > r.spec.paused_obs)
                if r.speculative_of:
                    pass    # origin still runs this suggestion; just drop
                elif final or self._stop.is_set():
                    self._release(r.spec)
                elif progressed:
                    # park the trial: keep its suggestion pending, snapshot
                    # its progress marker, free the slot + lease; it
                    # resumes from checkpoint once the rung population
                    # shifts (or nothing else is left to run)
                    self._write_pause_marker(r.spec, stopped_at, value)
                    self._requeue.append(TrialSpec(
                        r.spec.trial_id, r.spec.assignment,
                        attempt=r.spec.attempt,
                        suggestion_id=r.spec.suggestion_id,
                        pauses=r.spec.pauses + 1,
                        paused_obs=self._observations))
                    self.store.append_log(
                        self.exp_id, rid,
                        f"paused at step={stopped_at} (lease released; "
                        f"awaiting promotion)")
                elif value is not None:
                    # re-paused with no new observations since the last
                    # pause: no promotion is coming — finalize as a pruned
                    # partial observation so the experiment can complete
                    goal_v = self._goal_value(value)
                    self._observe(r.spec, origin, goal_v,
                                  metadata={"trial_id": origin,
                                            "pruned": True, "paused": True,
                                            "pruned_at_step": stopped_at})
                else:
                    self._release(r.spec)
            elif err[0] == "stopped" and value is not None:
                # early-stopped: record the last rung value as a pruned
                # (partial) observation — informative, not a failure
                goal_v = self._goal_value(value)
                self._observe(r.spec, origin, goal_v,
                              metadata={"trial_id": origin, "pruned": True,
                                        "pruned_at_step": stopped_at})
            elif err[0] == "stopped":
                # stopped before any report (delete/shutdown): hand the
                # unevaluated suggestion back to the budget
                self._release(r.spec)
            elif err[0] == "preempted" or (err[0] == "crashed"
                                           and r.spec.attempt
                                           < self.cfg.max_retries):
                if not final and not self._stop.is_set():
                    self._requeue.append(TrialSpec(
                        r.spec.trial_id, r.spec.assignment,
                        attempt=r.spec.attempt + 1,
                        suggestion_id=r.spec.suggestion_id))
                    self.store.append_log(self.exp_id, rid,
                                          f"requeued after {err[0]}")
                else:
                    self._release(r.spec)
            else:
                self._observe(r.spec, origin, None, failed=True,
                              metadata={"trial_id": origin,
                                        "reason": err[1]})
        if done:
            self._write_status(force=final)
