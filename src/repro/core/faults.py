"""Fault injection + recovery plumbing.

The scheduler already implements the recovery policies (retry, requeue on
preemption, speculative re-execution); this module provides deterministic
fault *injection* so those paths are testable without real node failures —
the same role chaos testing plays for the paper's Kubernetes deployment.

Two layers:

* trial-level (:func:`wrap_trial`, :class:`FaultPolicy`) — crash / NaN /
  straggler injection keyed by assignment hash;
* fleet-level (:class:`FaultPlan`) — a deterministic, tick-indexed
  schedule of *edge* faults (partition / drop / delay between named
  endpoints: ``worker-3 ↔ shard-1``, ``manager ↔ shard-0``), threaded
  through ``HTTPClient`` (``fault_gate=``), ``FleetClient``
  (``fault_plan=``) and the manager probe loop.  Injected partitions
  raise :class:`InjectedPartition` — a ``ConnectionRefusedError``
  subclass — so they traverse the *real* transport error-handling and
  retry paths, replacing wall-clock kill −9 races with reproducible
  partition schedules.
"""
from __future__ import annotations

import fnmatch
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster


class InjectedCrash(RuntimeError):
    pass


class InjectedPartition(ConnectionRefusedError):
    """A fault-plan edge fault.  Subclasses ``ConnectionRefusedError`` so
    transport code treats an injected partition exactly like a refused
    connect (the message provably never reached the far side — safe to
    retry any verb)."""


class FaultPlan:
    """Deterministic, tick-indexed schedule of fleet edge faults.

    A rule is ``{op, src, dst, at, until, delay_s, p}``:

      op       ``partition`` (raise on every message), ``drop`` (raise
               with probability ``p``, seeded) or ``delay`` (sleep
               ``delay_s`` then pass).
      src/dst  endpoint labels; ``fnmatch`` patterns (``"*"``, ``"w*"``)
               are allowed and the rule matches either direction of the
               edge.
      at       first tick (inclusive) the rule is active.
      until    last tick (exclusive); ``None`` = until healed/forever.

    Ticks are a *logical* clock: the active FleetManager advances the
    plan once per probe tick (and tests drive :meth:`tick` directly), so
    a schedule replays identically regardless of wall-clock timing.
    Helpers (:meth:`partition`, :meth:`heal`) edit the schedule live —
    handy for test scripts that interleave faults with assertions.
    """

    def __init__(self, rules: Optional[List[Dict[str, Any]]] = None,
                 seed: int = 0):
        self._lock = threading.Lock()
        self.rules: List[Dict[str, Any]] = [dict(r) for r in (rules or [])]
        self.rng = np.random.default_rng(seed)
        self._tick = 0
        # observability: (src, dst) -> count of messages faulted
        self.dropped: Dict[Tuple[str, str], int] = {}
        self.delayed: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------- schedule
    def add(self, op: str, src: str, dst: str, at: int = 0,
            until: Optional[int] = None, delay_s: float = 0.0,
            p: float = 1.0) -> "FaultPlan":
        with self._lock:
            self.rules.append({"op": op, "src": src, "dst": dst, "at": at,
                               "until": until, "delay_s": delay_s, "p": p})
        return self

    def partition(self, src: str, dst: str, at: int = 0,
                  until: Optional[int] = None) -> "FaultPlan":
        return self.add("partition", src, dst, at=at, until=until)

    def heal(self, src: str = "*", dst: str = "*") -> "FaultPlan":
        """End every open-ended rule matching the edge at the current
        tick (rules with an explicit ``until`` keep their schedule)."""
        with self._lock:
            for r in self.rules:
                if (r["until"] is None
                        and self._edge_match(r, src, dst)):
                    r["until"] = self._tick
        return self

    # ------------------------------------------------------------- clock
    def tick(self) -> int:
        with self._lock:
            self._tick += 1
            return self._tick

    @property
    def now(self) -> int:
        return self._tick

    # ------------------------------------------------------------- gating
    @staticmethod
    def _edge_match(rule: Dict[str, Any], src: str, dst: str) -> bool:
        m = fnmatch.fnmatch
        return ((m(src, rule["src"]) and m(dst, rule["dst"]))
                or (m(src, rule["dst"]) and m(dst, rule["src"])))

    def gate(self, src: str, dst: str) -> None:
        """Consult the plan for one message on edge ``src -> dst``: raise
        :class:`InjectedPartition` (partition, or seeded drop) or sleep
        (delay) per the rules active at the current tick."""
        with self._lock:
            tick = self._tick
            active = [r for r in self.rules
                      if r["at"] <= tick
                      and (r["until"] is None or tick < r["until"])
                      and self._edge_match(r, src, dst)]
            delay = 0.0
            for r in active:
                if r["op"] == "partition" or (
                        r["op"] == "drop"
                        and self.rng.uniform() < r.get("p", 1.0)):
                    self.dropped[(src, dst)] = \
                        self.dropped.get((src, dst), 0) + 1
                    raise InjectedPartition(
                        f"injected partition {src} -> {dst} @tick {tick}")
                if r["op"] == "delay":
                    delay = max(delay, r.get("delay_s", 0.0))
        if delay > 0.0:
            self.delayed[(src, dst)] = self.delayed.get((src, dst), 0) + 1
            time.sleep(delay)

    def edge_gate(self, src: str, dst: str) -> Callable[[], None]:
        """Zero-arg closure for transports that only know their own edge
        (``HTTPClient(fault_gate=...)``)."""
        return lambda: self.gate(src, dst)


@dataclass
class FaultPolicy:
    p_crash: float = 0.0         # trial raises before finishing
    p_nan: float = 0.0           # trial returns NaN (diverged model)
    p_slow: float = 0.0          # trial becomes a straggler
    slow_factor: float = 5.0
    seed: int = 0


def wrap_trial(trial_fn: Callable, policy: FaultPolicy) -> Callable:
    """Deterministic per-trial fault injection keyed by assignment hash."""
    def wrapped(assignment: Dict[str, Any], ctx):
        h = abs(hash(tuple(sorted((k, repr(v)) for k, v in
                                  assignment.items())))) % (2 ** 32)
        rng = np.random.default_rng(policy.seed ^ h)
        roll = rng.uniform()
        if roll < policy.p_crash:
            ctx.log("fault-injection: crash")
            raise InjectedCrash("injected crash")
        if roll < policy.p_crash + policy.p_nan:
            ctx.log("fault-injection: nan")
            return float("nan")
        if roll < policy.p_crash + policy.p_nan + policy.p_slow:
            ctx.log(f"fault-injection: straggler x{policy.slow_factor}")
            t0 = time.time()
            out = trial_fn(assignment, ctx)
            time.sleep((time.time() - t0) * (policy.slow_factor - 1.0))
            return out
        return trial_fn(assignment, ctx)
    return wrapped


class ChaosMonkey:
    """Background node-killer against a Cluster (cluster-level fault
    tolerance: revoked leases -> scheduler requeues from checkpoints)."""

    def __init__(self, cluster: Cluster, pool: str, period_s: float,
                 heal_s: Optional[float] = None, seed: int = 0):
        self.cluster = cluster
        self.pool = pool
        self.period_s = period_s
        self.heal_s = heal_s
        self.rng = np.random.default_rng(seed)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.kills = 0

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)

    def _loop(self):
        # The FIRST kill is lease-triggered, not clock-triggered: a fixed
        # pre-kill sleep races the workload — a short run (warm caches)
        # can complete inside one period, the monkey never fires, and a
        # test asserting "chaos happened" (kills >= 1) flakes.  Poll
        # until the pool actually holds a lease, kill immediately, then
        # fall into the periodic cadence.
        poll = max(0.001, self.period_s / 10.0)
        while not self._stop.is_set():
            if self.cluster.status()["pools"][self.pool]["leases"] > 0:
                self._kill_one()
                break
            if self._stop.wait(poll):
                return
        while not self._stop.wait(self.period_s):
            self._kill_one()

    def _kill_one(self):
        before = self.cluster.status()["pools"][self.pool]["chips"]
        self.cluster.fail_nodes(self.pool, 1)
        self.kills += 1
        if self.heal_s is not None:
            time.sleep(self.heal_s)
            self.cluster.scale(self.pool, before)       # node replaced
