"""Fault injection + recovery plumbing.

The scheduler already implements the recovery policies (retry, requeue on
preemption, speculative re-execution); this module provides deterministic
fault *injection* so those paths are testable without real node failures —
the same role chaos testing plays for the paper's Kubernetes deployment.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.cluster import Cluster


class InjectedCrash(RuntimeError):
    pass


@dataclass
class FaultPolicy:
    p_crash: float = 0.0         # trial raises before finishing
    p_nan: float = 0.0           # trial returns NaN (diverged model)
    p_slow: float = 0.0          # trial becomes a straggler
    slow_factor: float = 5.0
    seed: int = 0


def wrap_trial(trial_fn: Callable, policy: FaultPolicy) -> Callable:
    """Deterministic per-trial fault injection keyed by assignment hash."""
    def wrapped(assignment: Dict[str, Any], ctx):
        h = abs(hash(tuple(sorted((k, repr(v)) for k, v in
                                  assignment.items())))) % (2 ** 32)
        rng = np.random.default_rng(policy.seed ^ h)
        roll = rng.uniform()
        if roll < policy.p_crash:
            ctx.log("fault-injection: crash")
            raise InjectedCrash("injected crash")
        if roll < policy.p_crash + policy.p_nan:
            ctx.log("fault-injection: nan")
            return float("nan")
        if roll < policy.p_crash + policy.p_nan + policy.p_slow:
            ctx.log(f"fault-injection: straggler x{policy.slow_factor}")
            t0 = time.time()
            out = trial_fn(assignment, ctx)
            time.sleep((time.time() - t0) * (policy.slow_factor - 1.0))
            return out
        return trial_fn(assignment, ctx)
    return wrapped


class ChaosMonkey:
    """Background node-killer against a Cluster (cluster-level fault
    tolerance: revoked leases -> scheduler requeues from checkpoints)."""

    def __init__(self, cluster: Cluster, pool: str, period_s: float,
                 heal_s: Optional[float] = None, seed: int = 0):
        self.cluster = cluster
        self.pool = pool
        self.period_s = period_s
        self.heal_s = heal_s
        self.rng = np.random.default_rng(seed)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.kills = 0

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)

    def _loop(self):
        while not self._stop.wait(self.period_s):
            before = self.cluster.status()["pools"][self.pool]["chips"]
            revoked = self.cluster.fail_nodes(self.pool, 1)
            self.kills += 1
            if self.heal_s is not None:
                time.sleep(self.heal_s)
                self.cluster.scale(self.pool, before)   # node replaced
