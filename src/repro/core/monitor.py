"""Status rendering (paper Fig. 4: `sigopt status`) and cluster health."""
from __future__ import annotations

from typing import Any, Dict


def format_experiment_status(exp_id: str, st: Dict[str, Any]) -> str:
    lines = [
        f"Job Name: orchestrate-{exp_id}",
        f"Job Status: "
        f"{'Complete' if st.get('state') == 'complete' else 'Not Complete'}",
        f"Experiment Name: {st.get('name', '?')}",
        f"{st.get('observations', 0)} / {st.get('budget', '?')} Observations",
        f"{st.get('failures', 0)} Observation(s) failed",
    ]
    if st.get("running_trials") is not None:
        lines.append(f"Trial status: {st['running_trials']} Running")
    best = st.get("best")
    if best:
        lines.append(f"Best value: {best.get('value'):.6g} "
                     f"at {best.get('assignment')}")
    lines.append(f"View more in the experiment store "
                 f"(.orchestrate/experiments/{exp_id}/)")
    return "\n".join(lines)


def format_cluster_status(st: Dict[str, Any]) -> str:
    lines = [f"Cluster: {st['name']}"]
    for name, pool in st["pools"].items():
        lines.append(f"  pool {name:8s} [{pool['resource']}] "
                     f"{pool['free']}/{pool['chips']} chips free, "
                     f"{pool['leases']} active leases")
    return "\n".join(lines)
