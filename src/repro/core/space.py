"""Hyperparameter search spaces.

A ``Space`` is an ordered set of parameters (double / int / categorical,
optionally log-scaled) with a bijective codec to the unit cube — every
optimizer in ``core/suggest`` works in [0,1]^d and lets the space handle
types, bounds, and scaling (this mirrors how SigOpt's API separates the
experiment definition from the optimizer).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Assignment = Dict[str, Any]


def strip_internal(a: Assignment) -> Assignment:
    """Drop optimizer-internal ``__``-prefixed echo keys (constant-liar
    tokens, particle ids, ...) — the user-facing view of an assignment."""
    return {k: v for k, v in a.items() if not k.startswith("__")}


@dataclass(frozen=True)
class Param:
    name: str
    kind: str                                  # double | int | categorical
    low: float = 0.0
    high: float = 1.0
    log: bool = False
    choices: Tuple[Any, ...] = ()

    def __post_init__(self):
        if self.kind in ("double", "int"):
            if not self.high > self.low:
                raise ValueError(f"{self.name}: high must exceed low")
            if self.log and self.low <= 0:
                raise ValueError(f"{self.name}: log scale needs low > 0")
        elif self.kind == "categorical":
            if not self.choices:
                raise ValueError(f"{self.name}: categorical needs choices")
        else:
            raise ValueError(f"{self.name}: unknown kind {self.kind}")

    # --- unit-cube codec ---------------------------------------------------
    def to_unit(self, value) -> float:
        if self.kind == "categorical":
            return (self.choices.index(value) + 0.5) / len(self.choices)
        lo, hi = self.low, self.high
        if self.log:
            return (math.log(value) - math.log(lo)) / (math.log(hi) - math.log(lo))
        return (float(value) - lo) / (hi - lo)

    def from_unit(self, u: float):
        u = min(max(float(u), 0.0), 1.0)
        if self.kind == "categorical":
            idx = min(int(u * len(self.choices)), len(self.choices) - 1)
            return self.choices[idx]
        lo, hi = self.low, self.high
        if self.log:
            v = math.exp(math.log(lo) + u * (math.log(hi) - math.log(lo)))
        else:
            v = lo + u * (hi - lo)
        if self.kind == "int":
            return int(round(min(max(v, lo), hi)))
        return float(min(max(v, lo), hi))   # clamp exp/log float error

    def validate(self, value) -> bool:
        if self.kind == "categorical":
            return value in self.choices
        ok = self.low <= value <= self.high
        return ok and (self.kind != "int" or float(value).is_integer())


class Space:
    def __init__(self, params: Sequence[Param]):
        if len({p.name for p in params}) != len(params):
            raise ValueError("duplicate parameter names")
        self.params: Tuple[Param, ...] = tuple(params)

    # --- constructors -------------------------------------------------------
    @classmethod
    def from_config(cls, items: Sequence[Dict[str, Any]]) -> "Space":
        """Build from YAML/JSON dicts: {name, type, bounds|choices, log}."""
        ps = []
        for it in items:
            kind = it.get("type", "double")
            if kind == "categorical":
                ps.append(Param(it["name"], kind,
                                choices=tuple(it["choices"])))
            else:
                lo, hi = it.get("bounds", (it.get("min"), it.get("max")))
                ps.append(Param(it["name"], kind, low=float(lo), high=float(hi),
                                log=bool(it.get("log", False))))
        return cls(ps)

    # --- basics --------------------------------------------------------------
    def __len__(self):
        return len(self.params)

    @property
    def names(self) -> List[str]:
        return [p.name for p in self.params]

    def validate(self, a: Assignment) -> bool:
        return (set(a) == set(self.names)
                and all(p.validate(a[p.name]) for p in self.params))

    # --- codecs ---------------------------------------------------------------
    def to_unit(self, a: Assignment) -> np.ndarray:
        return np.array([p.to_unit(a[p.name]) for p in self.params])

    def from_unit(self, u: np.ndarray) -> Assignment:
        return {p.name: p.from_unit(u[i]) for i, p in enumerate(self.params)}

    # --- sampling ---------------------------------------------------------------
    def sample(self, rng: np.random.Generator, n: int = 1) -> List[Assignment]:
        u = rng.uniform(size=(n, len(self.params)))
        return [self.from_unit(row) for row in u]

    def grid(self, points_per_dim: int) -> List[Assignment]:
        axes = []
        for p in self.params:
            if p.kind == "categorical":
                axes.append([p.to_unit(c) for c in p.choices])
            else:
                axes.append(list((np.arange(points_per_dim) + 0.5)
                                 / points_per_dim))
        mesh = np.meshgrid(*axes, indexing="ij")
        flat = np.stack([m.ravel() for m in mesh], axis=-1)
        return [self.from_unit(row) for row in flat]

    def to_config(self) -> List[Dict[str, Any]]:
        out = []
        for p in self.params:
            if p.kind == "categorical":
                out.append({"name": p.name, "type": p.kind,
                            "choices": list(p.choices)})
            else:
                out.append({"name": p.name, "type": p.kind,
                            "bounds": [p.low, p.high], "log": p.log})
        return out
