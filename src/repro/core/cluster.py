"""Mesh-backed cluster abstraction (paper §2.2/§2.3/§3.4.1).

A ``Cluster`` owns heterogeneous resource pools — the TPU-native analogue of
the paper's mixed CPU/GPU EKS node groups.  Each pool has a capacity in
chips (plus min/max bounds for elastic scaling, mirroring the paper's
min_nodes/max_nodes YAML, Fig. 2) and an allocator that carves fixed-size
*slices* for trials.  On real hardware a slice maps to a contiguous device
submesh; in this container chips are placeholder capacity units and the
`devices` list carries whatever jax exposes.

Fault model: ``fail_nodes`` removes capacity and revokes affected leases —
the scheduler sees the revocation callback and requeues the trial from its
checkpoint (cluster-level fault tolerance).
"""
from __future__ import annotations

import threading
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax


@dataclass
class PoolConfig:
    name: str
    resource: str = "cpu"           # cpu | tpu
    chips: int = 4                  # current capacity
    min_chips: int = 0
    max_chips: int = 1 << 30
    chips_per_node: int = 1

    def to_json(self):
        return self.__dict__.copy()

    @classmethod
    def from_json(cls, d):
        return cls(**{k: d[k] for k in
                      ("name", "resource", "chips", "min_chips", "max_chips",
                       "chips_per_node") if k in d})


@dataclass
class ClusterConfig:
    cluster_name: str
    provider: str = "local"
    pools: List[PoolConfig] = field(default_factory=list)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ClusterConfig":
        pools = [PoolConfig.from_json(p) for p in d.get("pools", [])]
        if not pools:   # paper-style flat yaml: gpu/cpu sections
            for key in ("tpu", "gpu", "cpu"):
                if key in d:
                    sec = d[key]
                    pools.append(PoolConfig(
                        name=key, resource="tpu" if key != "cpu" else "cpu",
                        chips=int(sec.get("max_nodes", 1))
                        * int(sec.get("chips_per_node", 1)),
                        min_chips=int(sec.get("min_nodes", 0)),
                        max_chips=int(sec.get("max_nodes", 1))
                        * int(sec.get("chips_per_node", 1)),
                        chips_per_node=int(sec.get("chips_per_node", 1))))
        return cls(cluster_name=d.get("cluster_name", "cluster"),
                   provider=d.get("cloud_provider", d.get("provider",
                                                          "local")),
                   pools=pools)

    def to_json(self):
        return {"cluster_name": self.cluster_name, "provider": self.provider,
                "pools": [p.to_json() for p in self.pools]}


@dataclass
class SliceLease:
    lease_id: str
    pool: str
    chips: int
    devices: List[Any] = field(default_factory=list)
    revoked: bool = False
    on_revoke: Optional[Callable[["SliceLease"], None]] = None


class Cluster:
    def __init__(self, config: ClusterConfig):
        self.config = config
        self.name = config.cluster_name
        self._lock = threading.Lock()
        self._free: Dict[str, int] = {p.name: p.chips for p in config.pools}
        self._caps: Dict[str, PoolConfig] = {p.name: p for p in config.pools}
        self._leases: Dict[str, SliceLease] = {}
        self._devices = list(jax.devices())

    # ------------------------------------------------------------ allocation
    def allocate(self, pool: str, chips: int,
                 on_revoke=None) -> Optional[SliceLease]:
        """Carve a slice; None if the pool lacks capacity (admission ctl)."""
        with self._lock:
            if pool not in self._free:
                raise KeyError(f"no pool {pool!r}; have {list(self._free)}")
            if self._free[pool] < chips:
                return None
            self._free[pool] -= chips
            lease = SliceLease(uuid.uuid4().hex[:8], pool, chips,
                               devices=self._devices[:max(1, min(
                                   chips, len(self._devices)))],
                               on_revoke=on_revoke)
            self._leases[lease.lease_id] = lease
            return lease

    def release(self, lease: SliceLease) -> None:
        with self._lock:
            if lease.lease_id in self._leases:
                del self._leases[lease.lease_id]
                if not lease.revoked:
                    self._free[lease.pool] += lease.chips

    # ------------------------------------------------------------- elasticity
    def scale(self, pool: str, chips: int) -> int:
        """Elastic resize within [min,max] (paper §2.2 on-demand cluster)."""
        with self._lock:
            cap = self._caps[pool]
            chips = max(cap.min_chips, min(chips, cap.max_chips))
            delta = chips - cap.chips
            cap.chips = chips
            self._free[pool] = max(0, self._free[pool] + delta)
            return chips

    # ------------------------------------------------------------- failures
    def fail_nodes(self, pool: str, n_nodes: int = 1) -> List[SliceLease]:
        """Simulate node loss: capacity shrinks, victim leases are revoked."""
        revoked = []
        with self._lock:
            cap = self._caps[pool]
            lost = min(n_nodes * cap.chips_per_node, cap.chips)
            cap.chips -= lost
            # take capacity from free first, then revoke leases
            from_free = min(lost, self._free[pool])
            self._free[pool] -= from_free
            lost -= from_free
            for lease in list(self._leases.values()):
                if lost <= 0:
                    break
                if lease.pool == pool and not lease.revoked:
                    lease.revoked = True
                    lost -= lease.chips
                    revoked.append(lease)
        for lease in revoked:
            if lease.on_revoke:
                lease.on_revoke(lease)
        return revoked

    # --------------------------------------------------------------- status
    def status(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "name": self.name,
                "pools": {
                    p.name: {"resource": p.resource, "chips": p.chips,
                             "free": self._free[p.name],
                             "leases": sum(1 for l in self._leases.values()
                                           if l.pool == p.name)}
                    for p in self._caps.values()},
            }
