"""Experiment/cluster lifecycle — the library behind the CLI verbs
(paper §3.1).  Cluster and experiment lifetimes are deliberately
dissociated (paper §2.6): destroying a cluster never deletes experiment
records from the store.

The orchestrator never holds a raw ``Optimizer`` and never reaches into
scheduler internals: all experiment state flows through a
``SuggestionClient`` (see API.md) — the in-process ``LocalClient`` by
default, or an ``HTTPClient`` when ``run(..., service=URL)`` drives the
experiment against a remote ``repro serve-api`` process.  Trial lifecycle
(intermediate metrics, early-stopping decisions, pause/resume) is likewise
service-owned: ``ctx.report`` flows through ``SuggestionClient.report``,
so N orchestrators on one experiment share one rung table.
"""
from __future__ import annotations

import importlib
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

from repro.api.client import SuggestionClient
from repro.api.protocol import ApiError, CreateExperiment
from repro.core.cluster import Cluster, ClusterConfig
from repro.core.experiment import ExperimentConfig
from repro.core.scheduler import Scheduler, TrialContext
from repro.core.store import Store


def resolve_entrypoint(spec: str) -> Callable:
    """'pkg.module:function' -> callable (the model-agnostic hook that
    replaces the paper's container entrypoint)."""
    mod, _, attr = spec.partition(":")
    fn = getattr(importlib.import_module(mod), attr or "main")
    return fn


class Orchestrator:
    def __init__(self, store_root: str = ".orchestrate",
                 client: Optional[SuggestionClient] = None):
        # deferred import: repro.api.local depends back on repro.core
        from repro.api.local import LocalClient
        self.store = Store(store_root)
        self.client = client or LocalClient(self.store)
        self._clusters: Dict[str, Cluster] = {}
        self._schedulers: Dict[str, Scheduler] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._exp_clients: Dict[str, SuggestionClient] = {}
        self._exp_clusters: Dict[str, str] = {}

    # ------------------------------------------------------------- clusters
    def cluster_create(self, config: Dict[str, Any]) -> Cluster:
        cc = ClusterConfig.from_json(config)
        if self.store.load_cluster(cc.cluster_name) is not None:
            raise ValueError(f"cluster {cc.cluster_name!r} already exists")
        cluster = Cluster(cc)
        self._clusters[cc.cluster_name] = cluster
        self.store.save_cluster(cc.cluster_name, cc.to_json())
        return cluster

    def cluster_get(self, name: str) -> Cluster:
        if name in self._clusters:
            return self._clusters[name]
        state = self.store.load_cluster(name)
        if state is None:
            raise KeyError(f"no cluster {name!r}")
        cluster = Cluster(ClusterConfig.from_json(state))
        self._clusters[name] = cluster
        return cluster

    def cluster_destroy(self, name: str) -> bool:
        """Tear down the cluster; experiment records remain in the store.
        Only experiments attached to *this* cluster are stopped — runs on
        other clusters (or cluster-less) keep going."""
        for exp_id, sched in list(self._schedulers.items()):
            if self._exp_clusters.get(exp_id) == name:
                sched.stop()
        self._clusters.pop(name, None)
        return self.store.delete_cluster(name)

    def cluster_status(self, name: str) -> Dict[str, Any]:
        return self.cluster_get(name).status()

    # ----------------------------------------------------------- experiments
    def _client_for(self, exp_id: str) -> SuggestionClient:
        return self._exp_clients.get(exp_id, self.client)

    def run(self, cfg: ExperimentConfig,
            trial_fn: Optional[Callable[[Dict[str, Any], TrialContext],
                                        float]] = None,
            cluster: Optional[str] = None, background: bool = False,
            exp_id: Optional[str] = None,
            service: Optional[str] = None,
            fleet: Optional[str] = None) -> str:
        """Start (or resume) an experiment.  Resuming an existing exp_id
        replays the observation log into the service's optimizer exactly
        once.  With ``service=URL`` the suggest/observe loop runs against
        a remote ``repro serve-api`` process; with ``fleet=URL`` it runs
        through a ``repro serve-fleet`` manager, which routes the
        experiment to its owning shard (API.md §Fleet).  Trial logs and
        checkpoints stay in this worker's local store either way."""
        if trial_fn is None:
            if not cfg.entrypoint:
                raise ValueError("need trial_fn or cfg.entrypoint")
            trial_fn = resolve_entrypoint(cfg.entrypoint)

        from repro.api.http import HTTPClient
        if fleet:
            from repro.fleet.router import FleetClient
            client = FleetClient(fleet)
        elif service:
            client = HTTPClient(service)
        else:
            client = self.client
        created = client.create_experiment(
            CreateExperiment(config=cfg.to_json(), exp_id=exp_id))
        exp_id = created.exp_id
        self._exp_clients[exp_id] = client
        if not (self.store.exp_dir(exp_id) / "config.json").exists():
            # remote service (or externally-stored client): local mirror
            # for trial logs / checkpoints / status
            self.store.create_experiment(exp_id, cfg)

        clu = self.cluster_get(cluster) if cluster else None
        sched = Scheduler(exp_id, cfg, client, clu, self.store, trial_fn)
        self._schedulers[exp_id] = sched
        if cluster:
            self._exp_clusters[exp_id] = cluster
        if background:
            th = threading.Thread(target=sched.run, daemon=True,
                                  name=f"sched-{exp_id}")
            th.start()
            self._threads[exp_id] = th
        else:
            sched.run()
        return exp_id

    def wait(self, exp_id: str, timeout: Optional[float] = None) -> None:
        th = self._threads.get(exp_id)
        if th:
            th.join(timeout)

    def status(self, exp_id: str) -> Dict[str, Any]:
        resp = self._client_for(exp_id).status(exp_id)
        st = dict(self.store.get_status(exp_id))   # local worker view
        remote = resp.to_json()
        remote.pop("exp_id", None)
        # the service owns observation truth; lifecycle state defers to a
        # local scheduler unless the service reached a terminal state
        local_state = st.get("state")
        terminal = ("complete", "stopped", "deleted", "failed")
        state = (remote["state"] if remote["state"] in terminal
                 or not local_state else local_state)
        st.update(remote)
        st["state"] = state
        sched = self._schedulers.get(exp_id)
        if sched:
            st["running_trials"] = sched.running_trials
            st["paused_trials"] = sched.paused_trials
        return st

    def logs(self, exp_id: str, follow: bool = False) -> Iterator[str]:
        stop = None
        sched = self._schedulers.get(exp_id)
        if sched is not None:
            stop = lambda: sched.finished
        return self.store.iter_logs(exp_id, follow=follow, stop=stop)

    def delete(self, exp_id: str) -> None:
        """Terminate all execution and free resources (paper §2.5)."""
        sched = self._schedulers.get(exp_id)
        if sched:
            sched.stop()
        try:
            self._client_for(exp_id).stop(exp_id, state="deleted")
        except ApiError:
            self.store.update_status(exp_id, state="deleted")
        self._exp_clients.pop(exp_id, None)
        self._exp_clusters.pop(exp_id, None)
