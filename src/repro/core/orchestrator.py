"""Experiment/cluster lifecycle — the library behind the six CLI verbs
(paper §3.1).  Cluster and experiment lifetimes are deliberately
dissociated (paper §2.6): destroying a cluster never deletes experiment
records from the store.
"""
from __future__ import annotations

import importlib
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

from repro.core.cluster import Cluster, ClusterConfig
from repro.core.experiment import ExperimentConfig, new_experiment_id
from repro.core.scheduler import Scheduler, TrialContext
from repro.core.store import Store
from repro.core.suggest.base import make_optimizer


def resolve_entrypoint(spec: str) -> Callable:
    """'pkg.module:function' -> callable (the model-agnostic hook that
    replaces the paper's container entrypoint)."""
    mod, _, attr = spec.partition(":")
    fn = getattr(importlib.import_module(mod), attr or "main")
    return fn


class Orchestrator:
    def __init__(self, store_root: str = ".orchestrate"):
        self.store = Store(store_root)
        self._clusters: Dict[str, Cluster] = {}
        self._schedulers: Dict[str, Scheduler] = {}
        self._threads: Dict[str, threading.Thread] = {}

    # ------------------------------------------------------------- clusters
    def cluster_create(self, config: Dict[str, Any]) -> Cluster:
        cc = ClusterConfig.from_json(config)
        if self.store.load_cluster(cc.cluster_name) is not None:
            raise ValueError(f"cluster {cc.cluster_name!r} already exists")
        cluster = Cluster(cc)
        self._clusters[cc.cluster_name] = cluster
        self.store.save_cluster(cc.cluster_name, cc.to_json())
        return cluster

    def cluster_get(self, name: str) -> Cluster:
        if name in self._clusters:
            return self._clusters[name]
        state = self.store.load_cluster(name)
        if state is None:
            raise KeyError(f"no cluster {name!r}")
        cluster = Cluster(ClusterConfig.from_json(state))
        self._clusters[name] = cluster
        return cluster

    def cluster_destroy(self, name: str) -> bool:
        """Tear down the cluster; experiment records remain in the store."""
        for exp_id, sched in list(self._schedulers.items()):
            sched.stop()
        self._clusters.pop(name, None)
        return self.store.delete_cluster(name)

    def cluster_status(self, name: str) -> Dict[str, Any]:
        return self.cluster_get(name).status()

    # ----------------------------------------------------------- experiments
    def run(self, cfg: ExperimentConfig,
            trial_fn: Optional[Callable[[Dict[str, Any], TrialContext],
                                        float]] = None,
            cluster: Optional[str] = None, background: bool = False,
            exp_id: Optional[str] = None) -> str:
        """Start (or resume) an experiment.  Resuming an existing exp_id
        replays the observation log into the optimizer — experiment-level
        checkpoint/restart."""
        resume = exp_id is not None and (
            self.store.exp_dir(exp_id) / "config.json").exists()
        if exp_id is None:
            exp_id = new_experiment_id()
        if not resume:
            self.store.create_experiment(exp_id, cfg)
        if trial_fn is None:
            if not cfg.entrypoint:
                raise ValueError("need trial_fn or cfg.entrypoint")
            trial_fn = resolve_entrypoint(cfg.entrypoint)

        optimizer = make_optimizer(cfg.optimizer, cfg.space, seed=cfg.seed,
                                   **cfg.optimizer_options)
        if resume:
            prior = self.store.load_observations(exp_id)
            if prior:
                optimizer.tell(prior)
        clu = self.cluster_get(cluster) if cluster else None
        sched = Scheduler(exp_id, cfg, optimizer, clu, self.store, trial_fn)
        if resume:
            sched._observations = len(self.store.load_observations(exp_id))
        self._schedulers[exp_id] = sched
        if background:
            th = threading.Thread(target=sched.run, daemon=True,
                                  name=f"sched-{exp_id}")
            th.start()
            self._threads[exp_id] = th
        else:
            sched.run()
        return exp_id

    def wait(self, exp_id: str, timeout: Optional[float] = None) -> None:
        th = self._threads.get(exp_id)
        if th:
            th.join(timeout)

    def status(self, exp_id: str) -> Dict[str, Any]:
        st = self.store.get_status(exp_id)
        try:
            cfg = self.store.load_config(exp_id)
            st["name"] = cfg.name
            st["budget"] = cfg.budget
        except FileNotFoundError:
            pass
        sched = self._schedulers.get(exp_id)
        if sched:
            st["running_trials"] = sched._in_flight()
        obs = self.store.load_observations(exp_id)
        st["observations"] = len(obs)
        st["failures"] = sum(1 for o in obs if o.failed)
        ok = [o for o in obs if not o.failed and o.value is not None]
        if ok:
            st["best"] = max(ok, key=lambda o: o.value).to_json()
        return st

    def logs(self, exp_id: str, follow: bool = False) -> Iterator[str]:
        stop = None
        sched = self._schedulers.get(exp_id)
        if sched is not None:
            stop = lambda: (sched._stop.is_set()
                            or sched._observations >= sched.cfg.budget)
        return self.store.iter_logs(exp_id, follow=follow, stop=stop)

    def delete(self, exp_id: str) -> None:
        """Terminate all execution and free resources (paper §2.5)."""
        sched = self._schedulers.get(exp_id)
        if sched:
            sched.stop()
        self.store.update_status(exp_id, state="deleted")
