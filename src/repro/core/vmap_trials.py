"""Population training: evaluate P hyperparameter configurations in ONE
compiled program via vmap over stacked parameters.

This is the beyond-paper, TPU-native realization of Orchestrate's "multiple
model configurations simultaneously" (§2.1).  Where the paper gives each
configuration its own Kubernetes pod, a TPU mesh prefers one SPMD program:
stack P model replicas along a leading axis, vmap the train step, and shard
that axis over the mesh (a `trial` axis carved out of `data`).  The MXU then
runs all P trials' matmuls as one batched workload — orchestration overhead
drops from per-pod container scheduling to zero.

Constraints (recorded in DESIGN.md §Arch-applicability): all trials in one
population must share parameter SHAPES; only leaf hyperparameters (lr,
weight decay, clip, init seed, ...) vary.  Topology search falls back to the
slice scheduler.

``population_train`` is exactly equivalent to P independent sequential runs
(tested in tests/test_population.py to ~1e-5).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class PopulationSpec:
    """Which hyperparameters vary across the population."""
    lr: bool = True
    weight_decay: bool = True
    b1: bool = False
    seed: bool = True


def _stack_init(model: LM, seeds: jnp.ndarray):
    """vmap model init over per-trial seeds -> stacked params (P, ...)."""
    return jax.vmap(lambda s: model.init(jax.random.key(s)))(seeds)


def make_population_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """Returns train_step((P-stacked state), batch (P,B,S...), hp vectors)."""
    model = LM(cfg)

    def one_step(state, batch, lr, wd):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        ocfg = opt_cfg  # wd enters via the update fn below
        import dataclasses as _dc
        new_p, new_opt, om = adamw_update(
            grads, state["opt"], state["params"],
            _dc.replace(ocfg, weight_decay=0.0), lr)
        # decoupled per-trial weight decay applied explicitly
        new_p = jax.tree.map(
            lambda np_, p_: (np_.astype(jnp.float32)
                             - lr * wd * p_.astype(jnp.float32)
                             ).astype(np_.dtype), new_p, state["params"])
        return ({"params": new_p, "opt": new_opt},
                {"loss": loss, **om})

    pop_step = jax.vmap(one_step, in_axes=(0, 0, 0, 0))
    return model, jax.jit(pop_step, donate_argnums=0)


class PopulationTrainer:
    """Train P trials simultaneously; the vmap executor behind the
    scheduler's `executor: vmap` mode."""

    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                 hp_names: Sequence[str] = ("lr", "weight_decay", "seed")):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.hp_names = tuple(hp_names)
        self.model, self.step = make_population_step(cfg, opt_cfg)

    def init_states(self, assignments: Sequence[Dict[str, Any]]):
        seeds = jnp.asarray([int(a.get("seed", i))
                             for i, a in enumerate(assignments)], jnp.uint32)
        params = _stack_init(self.model, seeds)
        opt = jax.vmap(adamw_init)(params)
        return {"params": params, "opt": opt}

    def hp_vectors(self, assignments: Sequence[Dict[str, Any]]):
        lr = jnp.asarray([float(a.get("lr", self.opt_cfg.lr))
                          for a in assignments], jnp.float32)
        wd = jnp.asarray([float(a.get("weight_decay",
                                      self.opt_cfg.weight_decay))
                          for a in assignments], jnp.float32)
        return lr, wd

    def train(self, assignments: Sequence[Dict[str, Any]],
              data_iter: Callable[[int], Dict[str, jnp.ndarray]],
              steps: int, eval_last: int = 8,
              report: Optional[Callable[[int, np.ndarray], None]] = None
              ) -> np.ndarray:
        """Run `steps` population steps; returns per-trial objective =
        mean loss over the last `eval_last` steps (lower is better)."""
        P = len(assignments)
        state = self.init_states(assignments)
        lr, wd = self.hp_vectors(assignments)
        tail: List[np.ndarray] = []
        for t in range(steps):
            batch = data_iter(t)           # (B, ...) shared across trials
            pbatch = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (P,) + a.shape), batch)
            state, metrics = self.step(state, pbatch, lr, wd)
            losses = np.asarray(metrics["loss"])
            if report is not None:
                report(t, losses)
            if t >= steps - eval_last:
                tail.append(losses)
        return np.mean(np.stack(tail), axis=0)
