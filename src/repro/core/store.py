"""System-of-record persistence (the paper's SigOpt role, §3.5): experiment
metadata, parameters, and performance live here *in perpetuity* — destroying
a cluster never touches the store (paper §2.6 dissociates the lifecycles).

Layout (JSON/JSONL; append-only observation + metric logs are crash-safe):
  <root>/experiments/<id>/config.json
  <root>/experiments/<id>/status.json          (incl. 'rungs' snapshot)
  <root>/experiments/<id>/epoch.json           (ownership fence record)
  <root>/experiments/<id>/observations.jsonl
  <root>/experiments/<id>/metrics/<trial>.jsonl
  <root>/experiments/<id>/logs/<trial>.log
  <root>/clusters/<name>.json
  <root>/fleet/<name>.json | events.jsonl      (fleet control plane)

Fencing (API.md §Fleet): each experiment carries an *ownership epoch* —
a ``[term, seq]`` pair compared lexicographically — plus an *owner
token* (the serving process incarnation).  ``claim_fence`` installs a
new (epoch, owner) and refuses to move the epoch backwards;
``check_fence`` is the per-write guard a shard runs before every
durable append: a shard whose (epoch, owner) no longer matches the
record has been superseded and gets :class:`FencedError` instead of a
silent lost write.
"""
from __future__ import annotations

import collections
import json
import os
import pathlib
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, TextIO, Tuple

from repro.core.experiment import ExperimentConfig
from repro.core.suggest.base import Observation

DEFAULT_ROOT = ".orchestrate"

LOG_HANDLE_CACHE = 64           # max simultaneously-open trial log files

EPOCH_ZERO = (0, 0)             # standalone services run at term 0


def _epoch(v) -> Tuple[int, int]:
    """Normalize a stored/wire epoch (2-list, tuple or None)."""
    if v is None:
        return EPOCH_ZERO
    term, seq = v
    return (int(term), int(seq))


class FencedError(Exception):
    """A write (or claim) carried a stale ownership epoch: a newer
    incarnation owns this experiment and the caller must stand down."""

    def __init__(self, exp_id: str, held, current, owner: str = ""):
        self.exp_id = exp_id
        self.held = _epoch(held)
        self.current = _epoch(current)
        self.owner = owner          # the incarnation that fenced us
        super().__init__(
            f"{exp_id}: epoch {list(self.held)} fenced by "
            f"{list(self.current)} (owner {owner or '?'})")


class Store:
    def __init__(self, root: str = DEFAULT_ROOT):
        self.root = pathlib.Path(root)
        (self.root / "experiments").mkdir(parents=True, exist_ok=True)
        (self.root / "clusters").mkdir(parents=True, exist_ok=True)
        (self.root / "fleet").mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()
        # status fast path: cache the serialized status.json keyed by
        # (mtime_ns, size, inode) so repeated read-modify-writes skip disk
        # reads but still see writes from other processes sharing the
        # root — set_status os.replace()s a fresh tmp file, so the inode
        # changes even for same-size rewrites within mtime granularity
        self._status_cache: Dict[str, Tuple[Tuple[int, int, int], str]] = {}
        # fence fast path: same (mtime_ns, size, inode) idiom — the
        # per-write check_fence costs one os.stat() while still seeing a
        # concurrent claim from another process sharing the root
        self._fence_cache: Dict[str, Tuple[Tuple[int, int, int], str]] = {}
        # log fast path: bounded LRU of open append handles (one syscall
        # per line instead of an open/write/close triplet)
        self._log_lock = threading.Lock()
        self._log_handles: "collections.OrderedDict[pathlib.Path, TextIO]" \
            = collections.OrderedDict()

    # ----------------------------------------------------------- experiments
    def exp_dir(self, exp_id: str) -> pathlib.Path:
        return self.root / "experiments" / exp_id

    def create_experiment(self, exp_id: str, cfg: ExperimentConfig) -> None:
        d = self.exp_dir(exp_id)
        (d / "logs").mkdir(parents=True, exist_ok=True)
        (d / "metrics").mkdir(parents=True, exist_ok=True)
        (d / "config.json").write_text(json.dumps(cfg.to_json(), indent=1))
        self.set_status(exp_id, {"state": "pending", "created": time.time()})

    def load_config(self, exp_id: str) -> ExperimentConfig:
        return ExperimentConfig.from_json(
            json.loads((self.exp_dir(exp_id) / "config.json").read_text()))

    def set_status(self, exp_id: str, status: Dict[str, Any]) -> None:
        p = self.exp_dir(exp_id) / "status.json"
        tmp = p.with_suffix(".tmp")
        text = json.dumps(status, indent=1)
        with self._lock:
            tmp.write_text(text)
            try:
                # stat the tmp file BEFORE the rename: os.replace keeps
                # its inode/mtime/size, and stat-ing p afterwards could
                # pair our text with a concurrent process's newer file
                st = os.stat(tmp)
                self._status_cache[exp_id] = (
                    (st.st_mtime_ns, st.st_size, st.st_ino), text)
            except OSError:
                self._status_cache.pop(exp_id, None)
            os.replace(tmp, p)  # atomic

    def get_status(self, exp_id: str) -> Dict[str, Any]:
        p = self.exp_dir(exp_id) / "status.json"
        with self._lock:
            try:
                st = os.stat(p)
            except OSError:
                self._status_cache.pop(exp_id, None)
                return {}
            key = (st.st_mtime_ns, st.st_size, st.st_ino)
            cached = self._status_cache.get(exp_id)
            if cached is not None and cached[0] == key:
                return json.loads(cached[1])
            text = p.read_text()
            self._status_cache[exp_id] = (key, text)
            return json.loads(text)

    def update_status(self, exp_id: str, **fields) -> Dict[str, Any]:
        with self._lock:   # atomic read-modify-write across threads
            st = self.get_status(exp_id)
            st.update(fields)
            self.set_status(exp_id, st)
        return st

    def list_experiments(self) -> List[str]:
        return sorted(p.name for p in (self.root / "experiments").iterdir()
                      if p.is_dir())

    # ---------------------------------------------------------------- fencing
    def fence_path(self, exp_id: str) -> pathlib.Path:
        return self.exp_dir(exp_id) / "epoch.json"

    def read_fence(self, exp_id: str) -> Tuple[Tuple[int, int], str]:
        """Current ``((term, seq), owner)`` for the experiment.  A missing
        record (pre-fencing store, or experiment never claimed) reads as
        ``(EPOCH_ZERO, "")`` — unowned, any claim wins."""
        p = self.fence_path(exp_id)
        with self._lock:
            try:
                st = os.stat(p)
            except OSError:
                self._fence_cache.pop(exp_id, None)
                return (EPOCH_ZERO, "")
            key = (st.st_mtime_ns, st.st_size, st.st_ino)
            cached = self._fence_cache.get(exp_id)
            if cached is not None and cached[0] == key:
                text = cached[1]
            else:
                text = p.read_text()
                self._fence_cache[exp_id] = (key, text)
            rec = json.loads(text)
            return (_epoch(rec.get("epoch")), rec.get("owner", ""))

    def claim_fence(self, exp_id: str, epoch, owner: str
                    ) -> Tuple[int, int]:
        """Install ``(epoch, owner)`` as the experiment's fence record.

        The epoch may never move backwards: a claim below the stored
        epoch raises :class:`FencedError` (the claimant is a zombie
        acting on a stale map).  An *equal*-epoch claim succeeds and
        swaps the owner token — last adopter wins, which is exactly the
        config-less re-adoption path within one map version — and a
        higher epoch is a manager-granted handover.  Returns the epoch
        now in force."""
        epoch = _epoch(epoch)
        with self._lock:
            cur, cur_owner = self.read_fence(exp_id)
            if epoch < cur:
                raise FencedError(exp_id, epoch, cur, cur_owner)
            p = self.fence_path(exp_id)
            tmp = p.with_suffix(".tmp")
            text = json.dumps({"epoch": list(epoch), "owner": owner,
                               "time": time.time()})
            tmp.write_text(text)
            try:
                st = os.stat(tmp)
                self._fence_cache[exp_id] = (
                    (st.st_mtime_ns, st.st_size, st.st_ino), text)
            except OSError:
                self._fence_cache.pop(exp_id, None)
            os.replace(tmp, p)  # atomic
            return epoch

    def check_fence(self, exp_id: str, epoch, owner: str) -> None:
        """Per-write guard: raise :class:`FencedError` unless ``(epoch,
        owner)`` still matches the stored record.  One os.stat() on the
        hot path (cache idiom of :meth:`get_status`)."""
        epoch = _epoch(epoch)
        cur, cur_owner = self.read_fence(exp_id)
        if cur == EPOCH_ZERO and not cur_owner:
            return              # unowned / pre-fencing store: no fence
        if cur > epoch or (cur == epoch and cur_owner != owner):
            raise FencedError(exp_id, epoch, cur, cur_owner)

    # ------------------------------------------------------------ fleet state
    # Control-plane files for the FleetManager (leader lease, rebuildable
    # state snapshot, crash-safe rebalance journal, audit/event tail).
    # All snapshots use the same atomic tmp+replace discipline as
    # set_status so a reader never sees a torn file.

    def fleet_path(self, name: str) -> pathlib.Path:
        return self.root / "fleet" / name

    def write_fleet_state(self, name: str, state: Dict[str, Any]) -> None:
        p = self.fleet_path(f"{name}.json")
        tmp = p.with_suffix(".tmp")
        with self._lock:
            tmp.write_text(json.dumps(state, indent=1))
            os.replace(tmp, p)  # atomic

    def read_fleet_state(self, name: str) -> Optional[Dict[str, Any]]:
        p = self.fleet_path(f"{name}.json")
        try:
            return json.loads(p.read_text())
        except (OSError, ValueError):
            return None

    def clear_fleet_state(self, name: str) -> bool:
        p = self.fleet_path(f"{name}.json")
        try:
            p.unlink()
            return True
        except OSError:
            return False

    def append_fleet_event(self, record: Dict[str, Any]) -> None:
        """Append one record to the fleet event tail (``fleet/
        events.jsonl``) — the audit/replay stream a standby manager tails
        to rebuild worker holdings between state snapshots."""
        self._append_line(self.fleet_path("events.jsonl"),
                          json.dumps(record))

    def load_fleet_events(self, limit: int = 0) -> List[Dict[str, Any]]:
        p = self.fleet_path("events.jsonl")
        if not p.exists():
            return []
        out = []
        for line in p.read_text().splitlines():
            if line.strip():
                out.append(json.loads(line))
        return out[-limit:] if limit else out

    # ----------------------------------------------------------- observations
    def append_observation(self, exp_id: str, obs: Observation,
                           trial_id: str = "",
                           suggestion_id: str = "") -> None:
        rec = obs.to_json()
        rec["trial_id"] = trial_id
        if suggestion_id:
            # persisted so an adopting incarnation can rebuild its
            # duplicate-observe dedupe set from the log (fleet fencing:
            # exactly-once observes across ownership handovers)
            rec["suggestion_id"] = suggestion_id
        rec["time"] = time.time()
        with self._lock:
            with open(self.exp_dir(exp_id) / "observations.jsonl", "a") as f:
                f.write(json.dumps(rec) + "\n")

    def load_observation_records(self, exp_id: str) -> List[Dict[str, Any]]:
        """Raw observation-log records (assignment/value plus trial_id,
        suggestion_id, time) in append order."""
        p = self.exp_dir(exp_id) / "observations.jsonl"
        if not p.exists():
            return []
        out = []
        for line in p.read_text().splitlines():
            if line.strip():
                out.append(json.loads(line))
        return out

    def load_observations(self, exp_id: str) -> List[Observation]:
        return [Observation.from_json(r)
                for r in self.load_observation_records(exp_id)]

    # ---------------------------------------------------------------- metrics
    def metric_path(self, exp_id: str, trial_id: str) -> pathlib.Path:
        return self.exp_dir(exp_id) / "metrics" / f"{trial_id}.jsonl"

    def append_metric(self, exp_id: str, trial_id: str,
                      record: Dict[str, Any]) -> None:
        """Append one progress record to the trial's metric stream (the
        service-side truth for early-stopping rung replay — same
        append-only contract as the observation log)."""
        p = self.metric_path(exp_id, trial_id)
        if not p.parent.exists():
            p.parent.mkdir(parents=True, exist_ok=True)
        self._append_line(p, json.dumps(record))

    def load_metrics(self, exp_id: str,
                     trial_id: Optional[str] = None) -> List[Dict[str, Any]]:
        """Metric records for one trial, or the whole experiment merged in
        ``seq`` order (the service-assigned stream position), so a restart
        replays rung history in the exact original interleaving."""
        mdir = self.exp_dir(exp_id) / "metrics"
        paths = ([self.metric_path(exp_id, trial_id)] if trial_id
                 else sorted(mdir.glob("*.jsonl")) if mdir.exists() else [])
        out: List[Dict[str, Any]] = []
        for p in paths:
            if not p.exists():
                continue
            for line in p.read_text().splitlines():
                if line.strip():
                    out.append(json.loads(line))
        out.sort(key=lambda r: r.get("seq", 0))
        return out

    # ----------------------------------------------------------------- logs
    def log_path(self, exp_id: str, trial_id: str) -> pathlib.Path:
        return self.exp_dir(exp_id) / "logs" / f"{trial_id}.log"

    def append_log(self, exp_id: str, trial_id: str, line: str) -> None:
        self._append_line(self.log_path(exp_id, trial_id),
                          line.rstrip("\n"))

    def _append_line(self, p: pathlib.Path, line: str) -> None:
        """One write+flush through the bounded LRU of open append handles
        (shared by trial logs and metric streams)."""
        with self._log_lock:
            f = self._log_handles.get(p)
            if f is None or f.closed:
                f = open(p, "a")
                self._log_handles[p] = f
                while len(self._log_handles) > LOG_HANDLE_CACHE:
                    _, old = self._log_handles.popitem(last=False)
                    try:
                        old.close()
                    except OSError:
                        pass
            else:
                self._log_handles.move_to_end(p)
            f.write(line + "\n")
            f.flush()   # tail/iter_logs readers must see every line

    def release_handle(self, p: pathlib.Path) -> bool:
        """Evict one cached append handle (a trial reached a terminal
        state and its metric/log stream will never grow again).  At fleet
        scale this is what keeps open-file count proportional to *live*
        trials instead of total trials; a later append transparently
        reopens.  Returns True when a handle was actually closed."""
        with self._log_lock:
            f = self._log_handles.pop(p, None)
        if f is None:
            return False
        try:
            f.close()
        except OSError:
            pass
        return True

    def open_handles(self) -> int:
        """Current size of the append-handle LRU (cap/eviction tests)."""
        with self._log_lock:
            return len(self._log_handles)

    def close_logs(self) -> None:
        """Flush and close all cached trial-log handles."""
        with self._log_lock:
            for f in self._log_handles.values():
                try:
                    f.close()
                except OSError:
                    pass
            self._log_handles.clear()

    def __del__(self):
        try:
            self.close_logs()
        except Exception:
            pass

    def iter_logs(self, exp_id: str, follow: bool = False,
                  poll: float = 0.2, stop=None) -> Iterator[str]:
        """Aggregate all trial logs of one experiment, tagged by trial —
        paper §2.4: 'recover all logs associated with a single experiment,
        irrespective of how parallel configurations were distributed'."""
        log_dir = self.exp_dir(exp_id) / "logs"
        offsets: Dict[str, int] = {}
        while True:
            emitted = False
            for p in sorted(log_dir.glob("*.log")):
                text = p.read_text()
                off = offsets.get(p.name, 0)
                if len(text) > off:
                    for line in text[off:].splitlines():
                        yield f"[{p.stem}] {line}"
                        emitted = True
                    offsets[p.name] = len(text)
            if not follow:
                return
            if stop is not None and stop() and not emitted:
                return
            time.sleep(poll)

    # -------------------------------------------------------------- clusters
    def save_cluster(self, name: str, state: Dict[str, Any]) -> None:
        p = self.root / "clusters" / f"{name}.json"
        p.write_text(json.dumps(state, indent=1))

    def load_cluster(self, name: str) -> Optional[Dict[str, Any]]:
        p = self.root / "clusters" / f"{name}.json"
        return json.loads(p.read_text()) if p.exists() else None

    def delete_cluster(self, name: str) -> bool:
        p = self.root / "clusters" / f"{name}.json"
        if p.exists():
            p.unlink()
            return True
        return False

    def list_clusters(self) -> List[str]:
        return sorted(p.stem for p in (self.root / "clusters").glob("*.json"))
