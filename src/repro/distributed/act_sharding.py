"""Activation sharding constraints, threaded to the model via a contextvar.

Without anchors, SPMD propagation from fully-sharded parameters onto
activations picks feature-dim shardings that conflict with the batch/seq
sharding of the inputs, producing "involuntary full rematerialization"
resharding chains in the backward pass.  The launcher sets the intended
activation spec around tracing; the model calls ``constrain`` at layer
boundaries.  No mesh context (unit tests, population vmap with mismatched
rank) -> no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_SPEC: contextvars.ContextVar[Optional[P]] = contextvars.ContextVar(
    "repro_act_spec", default=None)


@contextlib.contextmanager
def activation_sharding(spec: Optional[P]):
    tok = _SPEC.set(spec)
    try:
        yield
    finally:
        _SPEC.reset(tok)


def current_spec() -> Optional[P]:
    return _SPEC.get()


def constrain_at(x, batch_dim: int):
    """Anchor only dim ``batch_dim`` of x to the ambient batch axes — used
    for recurrent scan carries and time-major xs, whose sharding would
    otherwise be re-derived (and re-gathered) every loop iteration."""
    spec = _SPEC.get()
    if spec is None or getattr(x, "ndim", 0) <= batch_dim:
        return x
    parts = [None] * x.ndim
    parts[batch_dim] = spec[0] if len(spec) > 0 else None
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:
        return x


def constrain(x):
    """Anchor activations to the ambient (batch, seq) spec, rank-adaptively:
    (B, F) -> P(b, None); (B, S, ...) -> P(b, s, None, ...).  The stored spec
    is a 2-entry P(batch_axes, seq_axes)."""
    spec = _SPEC.get()
    if spec is None or getattr(x, "ndim", 0) < 2:
        return x
    b = spec[0] if len(spec) > 0 else None
    s = spec[1] if len(spec) > 1 else None
    if x.ndim == 2:
        full = P(b, None)
    else:
        full = P(b, s, *([None] * (x.ndim - 2)))
    try:
        return jax.lax.with_sharding_constraint(x, full)
    except Exception:       # no ambient mesh / abstract eval: stay a no-op
        return x
