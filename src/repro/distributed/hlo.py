"""Static analysis of compiled (post-SPMD, per-device) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
``while`` body ONCE, so any scanned model (scan-over-layers, chunked
attention, chunkwise mLSTM) under-reports FLOPs/bytes by the trip count
(verified: a 10-step scanned matmul reports 1 matmul of FLOPs).  This module
re-derives per-chip cost from ``compiled.as_text()``:

* computations are parsed into blocks with a per-block symbol table
  (name -> shape/bytes, parameters included);
* ``while`` ops multiply their body's cost by the trip count recovered from
  the loop condition (largest integer constant compared against — the form
  jax's scan lowers to);
* FLOPs: ``dot`` ops contribute 2 * result_elems * contracted_size
  (contracting dims parsed from dnums); dots inside fusion subcomputations
  are attributed to the callsite; convolutions counted analogously.
* bytes: per *top-level* instruction, result bytes + operand bytes (fusion
  internals excluded — only materialized buffers traffic HBM);
* collectives: ring-model per-chip ICI bytes (see ``_ici_bytes``), also
  scaled by trip counts.

All numbers are per-device because the partitioned module is per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction:  %name = <type> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\]{},]+))\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"(%?[\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[\w\[\]{},]+))")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    result_type: str
    opcode: str
    line: str


@dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # name -> type text
    params: List[str] = field(default_factory=list)       # in header order


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    ici_bytes: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)
    coll_bytes: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.ici_bytes += other.ici_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + v * mult


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers sit at column 0: "%name (params) -> ty {"
            if (line and not line.startswith(" ") and line.endswith("{")
                    and "->" in line):
                hdr = line[len("ENTRY "):] if line.startswith("ENTRY ") else line
                name = hdr.split("(", 1)[0].strip().lstrip("%").strip()
                cur = _Comp(name)
                if line.startswith("ENTRY"):
                    entry = cur.name
                # record parameter shapes from the header parens
                paren = line[line.find("("):line.rfind("->")]
                for pm in _PARAM_RE.finditer(paren):
                    pname = pm.group(1).lstrip("%")
                    cur.shapes[pname] = pm.group(2)
                    cur.params.append(pname)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name = m.group(1).lstrip("%")
            cur.instrs.append(_Instr(name, m.group(2), m.group(3), line))
            cur.shapes[name] = m.group(2)
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: _Comp) -> int:
    best = 1
    for ins in cond.instrs:
        for c in _CONST_INT_RE.findall(ins.line):
            best = max(best, int(c))
    return best


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    res_elems = 0
    for dt, dims in _shape_dims(ins.result_type):
        n = 1
        for d in dims:
            n *= d
        res_elems += n
    m = _CONTRACT_RE.search(ins.line)
    # operands: first two %refs after the opcode paren
    paren = ins.line[ins.line.find(ins.opcode) + len(ins.opcode):]
    ops = _OPERAND_RE.findall(paren)
    contract = 1
    if m is not None and ops:
        lhs_type = comp.shapes.get(ops[0], "")
        dims_list = _shape_dims(lhs_type)
        if dims_list:
            _, dims = dims_list[0]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * res_elems * contract


def _ici_bytes(kind: str, result_bytes: int, group: int) -> float:
    ring = (group - 1) / group if group > 1 else 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * ring
    if kind == "all-gather":
        return result_bytes * ring
    if kind == "reduce-scatter":
        return result_bytes * group * ring   # result is the shard
    if kind == "all-to-all":
        return result_bytes * ring
    return float(result_bytes)               # collective-permute


_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


_TRANS_OPS = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
              "logistic", "sine", "cosine"}


class HloAnalyzer:
    def __init__(self, text: str, n_devices: int):
        self.comps, self.entry = _parse_computations(text)
        self.n_devices = n_devices
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self._comp_cost(self.entry, top_level=True)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[key] = total  # guard cycles
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                m = _COND_BODY_RE.search(ins.line)
                if m:
                    trips = _trip_count(self.comps.get(m.group(1), _Comp("")))
                    total.add(self._comp_cost(m.group(2), top_level), trips)
                continue
            if op in ("fusion", "call", "custom-call", "conditional",
                      "async-start", "map", "reduce", "sort", "scatter",
                      "select-and-scatter", "reduce-window"):
                for sub in _CALLS_RE.findall(ins.line):
                    sc = self._comp_cost(sub, top_level=False)
                    # fusion internals: flops yes, bytes no
                    total.flops += sc.flops
                    total.transcendentals += sc.transcendentals
                    total.ici_bytes += sc.ici_bytes
                    for k, v in sc.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0) + v
            kind = op.replace("-start", "").replace("-done", "")
            if kind in _COLLECTIVES and not op.endswith("-done"):
                rb = _shape_bytes(ins.result_type)
                grp = _group_size(ins.line, self.n_devices)
                ici = _ici_bytes(kind, rb, grp)
                total.ici_bytes += ici
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0) + ici
            if op in ("dot", "convolution"):
                total.flops += _dot_flops(ins, comp)
            elif op in _TRANS_OPS:
                total.transcendentals += _shape_bytes(ins.result_type)
            # HBM traffic: only materialized (top-level or while-body)
            # buffers.  `copy` is excluded: in optimized HLO it is almost
            # always a loop-carry aliasing artifact that buffer assignment
            # elides on real hardware (charging it r/w inflated scanned
            # models by the full stacked-parameter size per iteration).
            if top_level and op not in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast",
                                        "copy", "copy-start", "copy-done"):
                total.bytes += self._instr_bytes(ins, comp)
        return total

    # ------------------------------------------------------------------
    _SLICING = ("dynamic-slice", "slice", "gather")

    def _operand_names(self, ins: _Instr) -> List[str]:
        paren = ins.line[ins.line.find("= ") + 2:]
        lo = paren.find("(")
        hi = paren.find(")", lo)
        return _OPERAND_RE.findall(paren[lo:hi + 1] if lo >= 0 else "")

    def _dus_update_bytes(self, ins: _Instr, comp: _Comp) -> int:
        ops = self._operand_names(ins)
        if len(ops) >= 2:
            return _shape_bytes(comp.shapes.get(ops[1], ""))
        return _shape_bytes(ins.result_type)

    def _instr_bytes(self, ins: _Instr, comp: _Comp) -> float:
        """Slice-aware read/write bytes of one materialized instruction.

        Dynamic-slice / slice / gather read only the slice, not the operand;
        dynamic-update-slice writes only the update region (the buffer is
        aliased).  Fusions are inspected: a fusion parameter consumed solely
        by slicing ops is charged the slice bytes; a fusion rooted in DUS is
        charged the update bytes as its write.
        """
        op = ins.opcode
        ops_named = self._operand_names(ins)
        if op in self._SLICING:
            return 2.0 * _shape_bytes(ins.result_type)
        if op == "dynamic-update-slice":
            return 2.0 * self._dus_update_bytes(ins, comp)
        if op == "fusion":
            subs = _CALLS_RE.findall(ins.line)
            sub = self.comps.get(subs[0]) if subs else None
            if sub is not None:
                # write side
                root = sub.instrs[-1] if sub.instrs else None
                if root is not None and root.opcode == "dynamic-update-slice":
                    b = float(self._dus_update_bytes(root, sub))
                else:
                    b = float(_shape_bytes(ins.result_type))
                # read side, per fusion parameter
                for i, on in enumerate(ops_named):
                    pname = sub.params[i] if i < len(sub.params) else None
                    if pname is None:
                        b += _shape_bytes(comp.shapes.get(on, ""))
                        continue
                    uses = [u for u in sub.instrs
                            if ("%" + pname) in u.line and u.opcode != "parameter"]
                    if uses and all(u.opcode in self._SLICING or
                                    (u.opcode == "dynamic-update-slice" and
                                     self._operand_names(u)[:1] == [pname])
                                    for u in uses):
                        b += sum(_shape_bytes(u.result_type)
                                 if u.opcode in self._SLICING
                                 else self._dus_update_bytes(u, sub)
                                 for u in uses)
                    else:
                        b += _shape_bytes(comp.shapes.get(on, ""))
                return b
        b = float(_shape_bytes(ins.result_type))
        for on in ops_named:
            b += _shape_bytes(comp.shapes.get(on, ""))
        return b


def analyze(text: str, n_devices: int) -> Dict:
    c = HloAnalyzer(text, n_devices).cost()
    return {
        "flops": c.flops,
        "bytes accessed": c.bytes,
        "transcendentals": c.transcendentals,
        "ici_bytes": c.ici_bytes,
        "collective_counts": c.coll_counts,
        "collective_bytes": c.coll_bytes,
    }


def collective_stats(hlo_text: str, n_devices: int) -> Dict[str, Dict]:
    """Back-compat summary used by the dry-run record."""
    a = analyze(hlo_text, n_devices)
    out = {k: {"count": a["collective_counts"].get(k, 0),
               "ici_bytes": a["collective_bytes"].get(k, 0.0)}
           for k in _COLLECTIVES}
    out["total"] = {"count": sum(v["count"] for v in out.values()),
                    "ici_bytes": a["ici_bytes"]}
    return out
