"""Greedy divisibility-aware sharding rules.

The assigned architectures have head counts (40, 96, 10, 24, ...) and vocab
sizes (49155, 51865, ...) that do not all divide a fixed 16x16 mesh, so a
static logical-axis table cannot work across the zoo.  Instead we assign mesh
axes to tensor dims greedily, largest-axis-to-largest-divisible-dim, which
fully shards every parameter whose dims allow it and gracefully degrades
(e.g. granite's 49155-row embedding shards only its d_model dim).

Conventions:
* ``skip_leading`` skips dim 0 — used for scanned layer stacks, whose leading
  ``repeats`` dim must stay unsharded (it is sliced every scan iteration).
* Activation batch/seq sharding comes from ``batch_seq_spec``: batch dim
  takes as many mesh axes as divide it (pod, data, model order), the sequence
  dim takes the leftovers (sequence parallelism when batch < chips).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


MIN_SHARD_ELEMS = 1 << 20   # replicate leaves below ~1M elements: sharding
                            # them buys nothing and seeds per-iteration
                            # gathers inside recurrent while-loops


def auto_spec(shape: Sequence[int], mesh: Mesh, *,
              skip_leading: bool = False,
              min_elems: int = MIN_SHARD_ELEMS) -> P:
    """Greedy PartitionSpec: assign each mesh axis (largest first) to the
    largest tensor dim still divisible by it.  Small leaves are replicated."""
    n_elems = 1
    for d in shape:
        n_elems *= d
    if n_elems < min_elems:
        return P(*([None] * len(shape)))
    assign = [[] for _ in shape]
    sizes = list(shape)
    start = 1 if (skip_leading and len(shape) > 1) else 0
    axes = sorted(mesh.shape.items(), key=lambda kv: -kv[1])
    for name, n in axes:
        if n == 1:
            continue
        best = -1
        for i in range(start, len(shape)):
            if sizes[i] % n == 0 and sizes[i] >= n:
                if best < 0 or sizes[i] > sizes[best]:
                    best = i
        if best >= 0:
            assign[best].append(name)
            sizes[best] //= n
    return P(*[tuple(a) if a else None for a in assign])


def tree_specs(tree: Any, mesh: Mesh, *, skip_leading_under: str = "groups"):
    """PartitionSpec pytree for a parameter pytree.  Leaves under a
    ``skip_leading_under`` key keep dim 0 (scan repeats) unsharded."""
    def walk(node, under):
        if isinstance(node, dict):
            return {k: walk(v, under or k == skip_leading_under)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, under) for v in node]
            return type(node)(t)
        return auto_spec(node.shape, mesh, skip_leading=under)
    return walk(tree, False)


def batch_seq_spec(mesh: Mesh, batch: int, seq: Optional[int]) -> P:
    """Sharding for (batch, seq, ...) activations: batch over leading mesh
    axes while divisible, remaining axes over seq (sequence parallelism)."""
    baxes, saxes = [], []
    b, s = batch, seq
    for name in mesh.axis_names:
        n = mesh.shape[name]
        if n == 1:
            continue
        if not saxes and b % n == 0 and b >= n:
            b //= n
            baxes.append(name)
        elif s is not None and s % n == 0 and s >= n:
            s //= n
            saxes.append(name)
    if seq is None:
        return P(tuple(baxes) if baxes else None)
    return P(tuple(baxes) if baxes else None,
             tuple(saxes) if saxes else None)


def shard_tree(tree: Any, mesh: Mesh, specs: Any):
    """NamedSharding pytree from a spec pytree (for in_shardings)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def sharded_bytes(shapes: Any, specs: Any, mesh: Mesh) -> int:
    """Exact per-device bytes of a pytree of ShapeDtypeStructs under a spec
    pytree — the analytic 'does it fit' number for the dry-run record."""
    import math as _math

    total = 0
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for s, spec in zip(flat_shapes, flat_specs):
        dims = list(s.shape)
        for i, entry in enumerate(spec):
            if entry is None or i >= len(dims):
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            f = 1
            for nm in names:
                f *= mesh.shape[nm]
            dims[i] = _math.ceil(dims[i] / f)
        n = 1
        for d in dims:
            n *= d
        total += n * np.dtype(s.dtype).itemsize
    return total
