"""Error-feedback int8 gradient compression for data-parallel reductions.

Distributed-optimization trick for the 1000-node regime: the data-axis
gradient all-reduce moves 4x fewer bytes by quantizing each gradient block
to int8 against a per-block max-abs scale; the quantization residual is
carried in an error-feedback buffer so SGD/Adam converge as if uncompressed
(Karimireddy et al., 2019).  ``compressed_psum`` is designed for use inside
``shard_map`` (see tests/test_compress.py); the Pallas kernel in
kernels/int8_quant.py is the TPU hot-path for `quantize`.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """float -> (int8 values, per-block f32 scales). Blockwise max-abs."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0           # (nb,)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    deq = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return deq.reshape(-1)[:n].reshape(shape)


def compressed_psum(grad: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: error-feedback compressed mean over `axis_name`.

    Returns (reduced_grad, new_error).  Wire bytes: 1 byte/elem (int8) +
    4/BLOCK bytes/elem of scales vs 4 bytes/elem uncompressed => ~3.9x less
    ICI traffic on the data axis.
    """
    corrected = grad.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    sent = dequantize(q, scale, grad.shape)
    new_err = corrected - sent                      # residual feedback
    n = jax.lax.psum(1, axis_name)
    reduced = jax.lax.psum(
        dequantize(q, scale, grad.shape), axis_name) / n
    return reduced, new_err


def compressed_psum_tree(grads, errs, axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, ne = compressed_psum(g, e, axis_name)
        out_g.append(rg.astype(g.dtype))
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)
