from repro.distributed.auto_shard import (auto_spec, batch_seq_spec,
                                          shard_tree, tree_specs)
from repro.distributed.hlo import collective_stats
from repro.distributed.roofline import (HW, roofline_terms)

__all__ = ["auto_spec", "batch_seq_spec", "shard_tree", "tree_specs",
           "collective_stats", "HW", "roofline_terms"]
