"""Roofline terms for TPU v5e from the compiled dry-run artifact.

Semantics: ``compiled.cost_analysis()`` on an SPMD-partitioned module reports
*per-partition* (per-chip) flops and bytes, so the three terms are computed
per chip directly:

  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = per-chip ring ICI bytes / ICI_BW   (single-link model;
               multi-link meshes only improve this)

MODEL_FLOPS uses the 6*N*D rule (N = params, D = tokens; N_active for MoE) so
the useful-compute ratio exposes remat / padding / replication waste.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


HW = _HW()


def roofline_terms(cost: Dict[str, float], ici_bytes_per_chip: float,
                   *, model_flops_per_chip: Optional[float] = None
                   ) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
    t_compute = flops / HW.peak_flops
    t_memory = bytes_accessed / HW.hbm_bw
    t_coll = ici_bytes_per_chip / HW.ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    out = {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "ici_bytes_per_chip": ici_bytes_per_chip,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops_per_chip:
        out["model_flops_per_chip"] = model_flops_per_chip
        out["useful_ratio"] = (model_flops_per_chip / flops) if flops else 0.0
        # fraction of the compute roofline actually achieved at the bound
        out["roofline_fraction"] = (
            (model_flops_per_chip / HW.peak_flops) / out["bound_s"]
            if out["bound_s"] else 0.0)
    return out
