"""Learning-rate schedules as pure functions of the step (traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(peak: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(peak, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, peak * w, cos(step - warmup))
    return fn
