"""AdamW with decoupled weight decay, bias correction, and global-norm
clipping.  Pure pytree functions: state shardings inherit parameter
shardings, and the whole update vmaps over a population axis (per-trial
learning rates / weight decay become vectors) — that is what
core/vmap_trials.py relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                      # peak lr (scheduled externally)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0                # 0 disables clipping


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
             for a in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 lr: Optional[jnp.ndarray] = None
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_opt_state, metrics).

    ``lr`` may be a traced scalar (schedule value or per-trial hyperparam);
    falls back to cfg.lr.  All moment math in f32 regardless of param dtype.
    """
    lr = cfg.lr if lr is None else lr
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
