"""The fleet manager's HTTP surface (stdlib-only, like ``serve_api``).

Endpoint map (schemas in API.md §Fleet):
  POST /fleet/experiments   admission-controlled create/resume; responds
                            with the CreateResponse plus the chosen
                            ``shard_id``/``shard_url`` and ``map_version``
  GET  /fleet/map           versioned ShardMap (routing table)
  POST /fleet/heartbeat     worker liveness beat -> {state, map_version,
                            period}
  POST /fleet/shards        attach a running ``serve-api`` shard at
                            runtime ({url, shard_id?, rebalance?}); the
                            manager rebalances the minimal disruption
                            set onto it (drain → adopt at a bumped
                            epoch → transfer)
  GET  /fleet/status        manager status (shards, workers, stats,
                            role/term)
  GET  /fleet/healthz       manager liveness

``serve_fleet`` assembles the whole thing: a FleetManager over N
in-process shards (each a real ``serve_api`` HTTP process-in-a-thread
over the *shared* store root) and/or externally-launched shard URLs.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Sequence, Union

from repro.api.http import ApiServer, serve_api
from repro.api.protocol import (ApiError, CreateExperiment, E_BAD_REQUEST,
                                E_INTERNAL, HeartbeatRequest)
from repro.core.store import Store
from repro.fleet.manager import FleetManager


class _FleetHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    manager: FleetManager = None            # set by FleetServer

    def log_message(self, fmt, *args):      # noqa: D102
        pass

    def _take_body(self) -> bytes:
        if getattr(self, "_body", None) is None:
            n = int(self.headers.get("Content-Length") or 0)
            self._body = self.rfile.read(n) if n else b""
        return self._body

    def _read_body(self) -> dict:
        raw = self._take_body() or b"{}"
        try:
            return json.loads(raw)
        except json.JSONDecodeError as e:
            raise ApiError(E_BAD_REQUEST, f"invalid JSON body: {e}")

    def _send(self, status: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _dispatch(self, method: str) -> None:
        self._body = None
        try:
            self._send(200, self._route(method))
        except ApiError as e:
            self._send(e.http_status, e.to_json())
        except Exception as e:  # noqa: the manager must answer, not die
            err = ApiError(E_INTERNAL, f"{type(e).__name__}: {e}")
            self._send(err.http_status, err.to_json())
        finally:
            self._take_body()   # drain for keep-alive reuse

    def _route(self, method: str) -> dict:
        m = self.manager
        path = self.path.split("?")[0].rstrip("/")
        if method == "GET" and path == "/fleet/healthz":
            return {"ok": True, "shards": len(m.ring)}
        if method == "GET" and path == "/fleet/map":
            return m.shard_map().to_json()
        if method == "GET" and path == "/fleet/status":
            return m.status()
        if method == "POST" and path == "/fleet/heartbeat":
            req = HeartbeatRequest.from_json(self._read_body())
            return m.heartbeat(req).to_json()
        if method == "POST" and path == "/fleet/shards":
            body = self._read_body()
            url = (body.get("url") or "").strip()
            if not url:
                raise ApiError(E_BAD_REQUEST, "shard url required")
            handle = m.add_shard(url, shard_id=body.get("shard_id"),
                                 rebalance=bool(body.get("rebalance", True)))
            out = handle.to_json()
            out["map_version"] = m.shard_map().version
            return out
        if method == "POST" and path == "/fleet/experiments":
            req = CreateExperiment.from_json(self._read_body())
            resp, shard_id, url, version = m.create_experiment(req)
            out = resp.to_json()
            out.update(shard_id=shard_id, shard_url=url,
                       map_version=version)
            return out
        raise ApiError(E_BAD_REQUEST, f"no route for {self.path!r}")

    def do_GET(self):   # noqa: N802
        self._dispatch("GET")

    def do_POST(self):  # noqa: N802
        self._dispatch("POST")


class FleetServer:
    """Owns the manager's HTTP listener, the FleetManager event loop, and
    any in-process shards ``serve_fleet`` spawned."""

    def __init__(self, manager: FleetManager, host: str = "127.0.0.1",
                 port: int = 0,
                 owned_shards: Optional[List[ApiServer]] = None):
        self.manager = manager
        self.owned_shards = list(owned_shards or [])
        handler = type("BoundFleetHandler", (_FleetHandler,),
                       {"manager": manager})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "FleetServer":
        self.manager.start()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="fleet-api", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.manager.start()
        self._httpd.serve_forever()

    def shutdown(self) -> None:
        """Graceful stop: listener first (no new work), then the event
        loop, then any shards this server owns."""
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self.manager.stop()
        for shard in self.owned_shards:
            try:
                shard.shutdown()
            except Exception:
                pass


def serve_fleet(store: Union[Store, str, None] = None, shards: int = 0,
                shard_urls: Sequence[str] = (), host: str = "127.0.0.1",
                port: int = 0, period: float = 1.0,
                **manager_kwargs) -> FleetServer:
    """Build (but don't start) a fleet.  ``shards`` in-process
    ``serve_api`` servers are spawned over the shared ``store`` root (the
    config that makes failover a config-less resume); ``shard_urls``
    attaches externally-launched ``repro serve-api`` processes.  At least
    one shard is required."""
    if shards > 0 and store is None:
        raise ValueError("in-process shards need a store root")
    standby = bool(manager_kwargs.get("standby"))
    if shards <= 0 and not shard_urls and not standby:
        # a warm standby may start empty — it inherits the fleet from
        # the control snapshot at takeover
        raise ValueError("a fleet needs at least one shard "
                         "(shards=N or shard_urls=[...])")
    if standby and store is None:
        raise ValueError("a standby manager needs the shared store root")
    # the shared store doubles as the manager's control plane (leader
    # lease, snapshot, event tail, rebalance journal) — that is what
    # makes a warm standby and crash-safe rebalance possible
    manager_kwargs.setdefault("store", store)
    manager = FleetManager(period=period, **manager_kwargs)
    owned: List[ApiServer] = []
    for i in range(shards):
        srv = serve_api(store, host=host).start()
        owned.append(srv)
        manager.add_shard(srv.url, shard_id=f"shard-{i}")
    for url in shard_urls:
        manager.add_shard(url)
    return FleetServer(manager, host=host, port=port, owned_shards=owned)
