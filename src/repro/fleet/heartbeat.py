"""Worker liveness: the registered → alive → suspect → dead state machine.

Every worker (scheduler process or service shard) is a ``WorkerRecord``
with a *monotonic-clock* deadline: wall-clock jumps (NTP steps, VM
suspend) must never mass-declare a fleet dead.  The registry is pure
bookkeeping — the FleetManager's event loop calls ``sweep()`` and acts on
the transitions it returns (dead workers get their pending suggestions
requeued; dead shards leave the hash ring).

States:
  registered  seen a registration but no heartbeat yet (grace = dead_after
              from registration, so a worker that registers and
              immediately wedges is still collected)
  alive       beat within ``suspect_after``
  suspect     missed beats past ``suspect_after`` — still routable, but
              the manager may start double-checking (probe) it
  dead        past ``dead_after``: leases revoked, holdings requeued,
              record retired after ``retire_after``
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

S_REGISTERED = "registered"
S_ALIVE = "alive"
S_SUSPECT = "suspect"
S_DEAD = "dead"


class WorkerRecord:
    __slots__ = ("worker_id", "kind", "url", "state", "last_beat",
                 "registered_at", "beats", "holdings", "on_dead", "meta")

    def __init__(self, worker_id: str, kind: str = "scheduler",
                 url: str = "", now: Optional[float] = None,
                 on_dead: Optional[Callable[["WorkerRecord"], None]] = None):
        now = time.monotonic() if now is None else now
        self.worker_id = worker_id
        self.kind = kind                    # scheduler | shard
        self.url = url
        self.state = S_REGISTERED
        self.last_beat = now                # registration counts as contact
        self.registered_at = now
        self.beats = 0
        # exp_id -> [suggestion_id, ...] — what to requeue on death
        self.holdings: Dict[str, List[str]] = {}
        self.on_dead = on_dead              # in-process revocation hook
        self.meta: Dict[str, Any] = {}

    def to_json(self) -> Dict[str, Any]:
        return {"worker_id": self.worker_id, "kind": self.kind,
                "url": self.url, "state": self.state, "beats": self.beats,
                "age_s": round(time.monotonic() - self.registered_at, 3),
                "silent_s": round(time.monotonic() - self.last_beat, 3),
                "holdings": {k: len(v) for k, v in self.holdings.items()}}


class WorkerRegistry:
    """Thread-safe liveness table.  ``period`` is the prescribed beat
    interval; the deadlines default to 2 periods (suspect) and 4 periods
    (dead) unless given explicitly — "requeued within 2 heartbeat
    periods" in the acceptance criteria is measured against
    ``dead_after``."""

    def __init__(self, period: float = 1.0,
                 suspect_after: Optional[float] = None,
                 dead_after: Optional[float] = None,
                 retire_after: float = 60.0):
        self.period = float(period)
        self.suspect_after = (self.period * 1.0 if suspect_after is None
                              else float(suspect_after))
        self.dead_after = (self.period * 2.0 if dead_after is None
                           else float(dead_after))
        if self.dead_after < self.suspect_after:
            self.dead_after = self.suspect_after
        self.retire_after = float(retire_after)
        self._lock = threading.Lock()
        self._workers: Dict[str, WorkerRecord] = {}

    # -------------------------------------------------------------- intake
    def register(self, worker_id: str, kind: str = "scheduler",
                 url: str = "", now: Optional[float] = None,
                 on_dead=None) -> WorkerRecord:
        with self._lock:
            rec = self._workers.get(worker_id)
            if rec is None or rec.state == S_DEAD:
                # a dead worker re-registering is a NEW incarnation: old
                # holdings were already requeued, start clean
                rec = WorkerRecord(worker_id, kind, url, now=now,
                                   on_dead=on_dead)
                self._workers[worker_id] = rec
            return rec

    def beat(self, worker_id: str, kind: str = "scheduler",
             holdings: Optional[Dict[str, List[str]]] = None,
             now: Optional[float] = None, url: str = "") -> str:
        """Record one heartbeat; auto-registers unknown workers (a
        manager restart must not orphan a running fleet).  Returns the
        worker's state AFTER the beat."""
        now = time.monotonic() if now is None else now
        with self._lock:
            rec = self._workers.get(worker_id)
            if rec is None or rec.state == S_DEAD:
                rec = WorkerRecord(worker_id, kind, url, now=now)
                self._workers[worker_id] = rec
            rec.last_beat = now
            rec.beats += 1
            if url:
                rec.url = url
            if rec.state in (S_REGISTERED, S_SUSPECT, S_ALIVE):
                rec.state = S_ALIVE
            if holdings is not None:
                rec.holdings = {k: list(v) for k, v in holdings.items()}
            return rec.state

    # --------------------------------------------------------------- sweep
    def sweep(self, now: Optional[float] = None) -> List[WorkerRecord]:
        """Advance every record's state against its monotonic deadline;
        returns the records that JUST transitioned to dead (each exactly
        once — the caller requeues their holdings).  Long-dead records
        are retired after ``retire_after``."""
        now = time.monotonic() if now is None else now
        newly_dead: List[WorkerRecord] = []
        with self._lock:
            for wid in list(self._workers):
                rec = self._workers[wid]
                silent = now - rec.last_beat
                if rec.state == S_DEAD:
                    if silent > self.dead_after + self.retire_after:
                        del self._workers[wid]
                    continue
                if silent >= self.dead_after:
                    rec.state = S_DEAD
                    newly_dead.append(rec)
                elif silent >= self.suspect_after \
                        and rec.state in (S_ALIVE, S_REGISTERED):
                    rec.state = S_SUSPECT
        return newly_dead

    # ------------------------------------------------------------- queries
    def get(self, worker_id: str) -> Optional[WorkerRecord]:
        with self._lock:
            return self._workers.get(worker_id)

    def state(self, worker_id: str) -> Optional[str]:
        rec = self.get(worker_id)
        return rec.state if rec else None

    def workers(self, kind: Optional[str] = None) -> List[WorkerRecord]:
        with self._lock:
            return [r for r in self._workers.values()
                    if kind is None or r.kind == kind]

    def to_json(self) -> Dict[str, Any]:
        with self._lock:
            return {wid: r.to_json() for wid, r in self._workers.items()}
