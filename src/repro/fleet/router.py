"""FleetClient: a ``SuggestionClient`` that makes a sharded fleet look
like one suggestion service.

Routing: creates go through the FleetManager (that's where admission
control lives — a saturated owner shard redirects the experiment, a
saturated fleet answers ``fleet_busy``); everything after the create goes
*directly* to the owning shard, so the manager is never on the
suggest/observe hot path.  The owner is resolved from the cached
:class:`~repro.api.protocol.ShardMap` — explicit override, else the
consistent-hash ring the client rebuilds locally from the map (blake2b is
process-stable, so client and manager always agree on ring ownership).

Failure handling: a routed call that fails with ``service unreachable`` /
``unknown_experiment`` / ``wrong_shard`` forces a map refresh, re-homes
the experiment onto the current owner (a config-less create resumes it
from the shared store — or from this client's cached config when the
store isn't shared), and retries once.  Until the manager has declared
the dead shard dead the retry may fail again; callers loop at their own
cadence (the scheduler already treats suggest errors as transient).

Heartbeats: a daemon thread beats every manager-prescribed ``period``
carrying this worker's *holdings* — the pending suggestion_ids it has
taken and not yet observed/released, per experiment.  If this process
dies, the manager requeues exactly those so survivors pick them up.

Batching (``batch=True``): the transport plane (API.md §Transport
batching) keeps one write-behind lane per *owning shard* — observe /
release / requeue / below-rung reports enqueue into the owner's lane and
ship as one ``BatchRequest`` per shard per flush trigger.  A per-op
``wrong_shard`` / ``fenced`` result re-homes and re-enqueues just that op
on the new owner's lane; holdings shrink only once a flush confirms the
op (a crash in between means the manager requeues an already-observed
suggestion, which the shard's closed-set dedupe absorbs — the safe
direction).  When a heartbeat is due, it piggybacks on the flush instead
of waiting for the periodic timer.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Dict, List, Optional, Set, Union

from repro.api.client import SuggestionClient
from repro.api.http import HTTPClient
from repro.api.protocol import (ApiError, BestResponse, CreateExperiment,
                                CreateResponse, Decision, E_FENCED,
                                E_INTERNAL, E_UNKNOWN_EXPERIMENT,
                                E_WRONG_SHARD, HeartbeatRequest,
                                HeartbeatResponse, ObserveRequest,
                                ObserveResponse, ReportRequest, ShardMap,
                                StatusResponse, SuggestBatch)
from repro.api.transport import (FLUSH_DEADLINE_S, FLUSH_MAX_OPS,
                                 DecisionGate, OP_OBSERVE, OP_RELEASE,
                                 OP_REPORT, OP_REQUEUE, WriteBehind)
from repro.fleet.hashring import HashRing

# ``fenced`` is retryable from the client's seat: the answering shard
# lost ownership, so a map refresh + re-route reaches the new owner
_RETRYABLE = (E_INTERNAL, E_UNKNOWN_EXPERIMENT, E_WRONG_SHARD, E_FENCED)


class _InprocFleet:
    """Manager access for a FleetClient living in the manager's process
    (tests, single-process fleets)."""

    def __init__(self, manager):
        self.manager = manager

    def fetch_map(self) -> ShardMap:
        return self.manager.shard_map()

    def create(self, req: CreateExperiment):
        resp, shard_id, _url, version = self.manager.create_experiment(req)
        return resp, shard_id, version

    def heartbeat(self, req: HeartbeatRequest) -> HeartbeatResponse:
        return self.manager.heartbeat(req)

    def shard_client(self, shard_id: str, url: str):
        handle = self.manager._shards.get(shard_id)
        if handle is None:
            raise ApiError(E_WRONG_SHARD, f"shard {shard_id!r} left the map")
        return handle.client

    def drop_urls(self, urls) -> None:
        pass

    def close(self) -> None:
        pass


class _HttpFleet:
    """Manager access over the wire (``repro serve-fleet``)."""

    def __init__(self, url: str, timeout: float = 30.0):
        self._c = HTTPClient(url, timeout=timeout)
        self._clients: Dict[str, HTTPClient] = {}   # url -> client
        self._lock = threading.Lock()
        self.timeout = timeout

    def fetch_map(self) -> ShardMap:
        return ShardMap.from_json(self._c._call("GET", "/fleet/map"))

    def create(self, req: CreateExperiment):
        d = self._c._call("POST", "/fleet/experiments", req.to_json())
        return (CreateResponse.from_json(d), d.get("shard_id", ""),
                int(d.get("map_version", 0)))

    def heartbeat(self, req: HeartbeatRequest) -> HeartbeatResponse:
        return HeartbeatResponse.from_json(
            self._c._call("POST", "/fleet/heartbeat", req.to_json()))

    def shard_client(self, shard_id: str, url: str) -> HTTPClient:
        if not url:
            raise ApiError(E_WRONG_SHARD,
                           f"shard {shard_id!r} has no routable url")
        with self._lock:
            c = self._clients.get(url)
            if c is None:
                c = self._clients[url] = HTTPClient(url, timeout=self.timeout)
            return c

    def drop_urls(self, urls) -> None:
        """Sever keep-alive connections to shards that left the map: a
        half-dead shard can keep serving already-open connections after
        its listener is gone, and routing through one would split writes
        across two owners."""
        with self._lock:
            dropped = [self._clients.pop(u) for u in urls
                       if u in self._clients]
        for c in dropped:
            c.close()

    def close(self) -> None:
        with self._lock:
            clients = list(self._clients.values())
            self._clients.clear()
        for c in clients:
            c.close()
        self._c.close()


class FleetClient(SuggestionClient):
    """One client for the whole fleet.  ``fleet`` is either a
    ``FleetManager`` instance (in-process) or a ``repro serve-fleet`` URL.

    ``replicas`` must match the manager's ring replicas (both default to
    64) — ring ownership is computed on both sides.
    """

    def __init__(self, fleet, worker_id: Optional[str] = None,
                 heartbeat: bool = True, timeout: float = 30.0,
                 replicas: int = 64, fault_plan=None,
                 batch: bool = False, batch_max: int = FLUSH_MAX_OPS,
                 batch_deadline: float = FLUSH_DEADLINE_S):
        if isinstance(fleet, str):
            self._proxy = _HttpFleet(fleet, timeout=timeout)
        else:
            self._proxy = _InprocFleet(fleet)
        self.worker_id = worker_id or f"sched-{uuid.uuid4().hex[:8]}"
        # chaos harness: a ``core.faults.FaultPlan`` consulted per routed
        # call (edge worker_id -> shard_id) and per heartbeat (-> manager)
        self._fault_plan = fault_plan
        # audit trail (bounded): heartbeat failures are recorded here
        # with a dedupe counter instead of being swallowed silently
        self.events: List[dict] = []
        self._beat_errors: Dict[str, int] = {}
        self._map = ShardMap(version=-1)
        self._ring = HashRing(replicas=replicas)
        self._replicas = replicas
        self._assigned: Dict[str, str] = {}   # exp_id -> shard_id (authoritative)
        self._configs: Dict[str, dict] = {}   # exp_id -> config (for re-home)
        self._holdings: Dict[str, Set[str]] = {}
        self._period = 1.0
        self._seq = 0
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self._last_beat = time.monotonic()
        self._wb: Optional[WriteBehind] = None
        self._gate: Optional[DecisionGate] = None
        if batch:
            self._gate = DecisionGate()
            self._wb = WriteBehind(self._send_shard_batch,
                                   max_ops=batch_max,
                                   deadline=batch_deadline,
                                   on_result=self._on_batch_result,
                                   after_flush=self._maybe_prompt_beat,
                                   name=f"wb-{self.worker_id}")
        self._refresh_map(force=True)
        if heartbeat:
            self.beat()                       # register before first suggest
            self._hb_thread = threading.Thread(target=self._beat_loop,
                                               name="fleet-heartbeat",
                                               daemon=True)
            self._hb_thread.start()

    # --------------------------------------------------------------- map
    def _refresh_map(self, force: bool = False,
                     version: Optional[int] = None) -> None:
        with self._lock:
            if not force and version is not None \
                    and version <= self._map.version:
                return
            m = self._proxy.fetch_map()
            if m.version == self._map.version and not force:
                return
            gone = [u for sid, u in self._map.shards.items()
                    if u and u not in m.shards.values()]
            self._map = m
            ring = HashRing(replicas=self._replicas)
            for sid in m.shards:
                ring.add(sid)
            self._ring = ring
            # assignments to shards that left the map fall back to the ring
            for exp, sid in list(self._assigned.items()):
                if sid not in m.shards:
                    del self._assigned[exp]
        # outside the lock: connection close can block on socket teardown
        if gone:
            self._proxy.drop_urls(gone)

    @property
    def map_version(self) -> int:
        with self._lock:
            return self._map.version

    def _owner(self, exp_id: str) -> str:
        with self._lock:
            sid = (self._map.overrides.get(exp_id)
                   or self._assigned.get(exp_id)
                   or self._ring.owner(exp_id))
            if sid is None or sid not in self._map.shards:
                sid = self._ring.owner(exp_id)
            if sid is None:
                raise ApiError(E_WRONG_SHARD, "fleet has no shards")
            return sid

    def _client_for(self, exp_id: str):
        with self._lock:
            sid = self._owner(exp_id)
            url = self._map.shards.get(sid, "")
        if self._fault_plan is not None:
            try:
                self._fault_plan.gate(self.worker_id, sid)
            except ConnectionRefusedError as e:
                # surface like a real transport failure so the routed
                # retry/refresh machinery handles injected partitions
                raise ApiError(E_INTERNAL, f"service unreachable: {e}")
        return self._proxy.shard_client(sid, url)

    # ----------------------------------------------------------- routing
    def _routed(self, exp_id: str, fn):
        """Run ``fn(shard_client)`` against the current owner; on a
        retryable failure refresh the map, re-home, retry once."""
        try:
            return fn(self._client_for(exp_id))
        except ApiError as e:
            if e.code not in _RETRYABLE:
                raise
            if e.code in (E_WRONG_SHARD, E_FENCED):
                # the answering shard disowned the experiment (drained or
                # fenced): the cached assignment is provably stale — drop
                # it so re-homing follows the ring/overrides, not the old
                # owner (re-creating there would resurrect a zombie)
                with self._lock:
                    self._assigned.pop(exp_id, None)
        self._refresh_map(force=True)
        self._rehome(exp_id)
        return fn(self._client_for(exp_id))

    def _rehome(self, exp_id: str) -> None:
        """Make sure the current owner is serving ``exp_id``: config-less
        create resumes it from the shared store; the cached config covers
        fleets without one.  Idempotent — resuming a live experiment is a
        no-op service-side."""
        cfg = self._configs.get(exp_id, {})
        try:
            client = self._client_for(exp_id)
            client.create_experiment(CreateExperiment(config=cfg,
                                                      exp_id=exp_id))
            with self._lock:
                self._assigned[exp_id] = self._owner(exp_id)
        except ApiError:
            pass    # let the retried call surface the real failure

    # ---------------------------------------------------------- batching
    def flush(self) -> None:
        """Drain every shard lane (no-op when batching is off)."""
        if self._wb is not None:
            self._wb.flush()

    def _enqueue_op(self, kind: str, payload: dict, exp_id: str) -> None:
        self._wb.enqueue(kind, payload, lane=self._owner(exp_id))

    def _send_shard_batch(self, shard_id, req):
        """WriteBehind transport: one batch per owning shard.  Works over
        both fleet flavors — ``LocalClient`` and ``HTTPClient`` expose
        the same ``apply_batch``."""
        with self._lock:
            url = self._map.shards.get(shard_id, "")
            known = shard_id in self._map.shards
        if not known:
            raise ApiError(E_WRONG_SHARD, f"shard {shard_id!r} left the map")
        if self._fault_plan is not None:
            try:
                self._fault_plan.gate(self.worker_id, shard_id)
            except ConnectionRefusedError as e:
                raise ApiError(E_INTERNAL, f"service unreachable: {e}")
        return self._proxy.shard_client(shard_id, url).apply_batch(req)

    def _on_batch_result(self, lane, op, result, err) -> bool:
        """Per-op outcome from a shipped batch (WriteBehind hook)."""
        p = op.payload
        if err is None:
            if op.kind == OP_REPORT:
                self._gate.note((p.get("exp_id"),
                                 p.get("suggestion_id") or p.get("trial_id")),
                                Decision.from_json(result.result))
            else:
                # confirmed on the owner: the holding may shrink now (and
                # only now — dropping before confirmation could strand a
                # suggestion the manager no longer knows to requeue)
                self._drop_holding(p.get("exp_id", ""),
                                   p.get("suggestion_id", ""))
            return False
        exp_id = p.get("exp_id", "")
        if err.code in _RETRYABLE and op.attempts < 2:
            # single-op re-home: wrong_shard / fenced / unreachable means
            # *this op's* owner moved — refresh, re-home, re-enqueue just
            # this op on the new owner's lane (the rest of the batch
            # already landed where it belonged)
            try:
                if err.code in (E_WRONG_SHARD, E_FENCED):
                    with self._lock:
                        self._assigned.pop(exp_id, None)
                self._refresh_map(force=True)
                self._rehome(exp_id)
                self._wb.enqueue(op.kind, p, lane=self._owner(exp_id),
                                 attempts=op.attempts + 1)
                return True
            except ApiError:
                pass        # fall through to terminal accounting
        self._drop_holding(exp_id, p.get("suggestion_id", ""))
        with self._lock:
            self.events.append({"event": "batch_op_failed", "op": op.kind,
                                "exp_id": exp_id, "code": err.code,
                                "error": err.message, "time": time.time()})
            if len(self.events) > 128:
                del self.events[:64]
        return False    # WriteBehind stats/op_errors record it too

    def _maybe_prompt_beat(self) -> None:
        """Flush piggyback: if a heartbeat is due, trigger it now instead
        of waiting out the periodic timer (holdings changed by the batch
        reach the manager on the flush cadence)."""
        if self._hb_thread is None:
            return
        with self._lock:
            due = time.monotonic() - self._last_beat >= self._period
        if due:
            self._wake.set()

    # ---------------------------------------------------------- protocol
    def create_experiment(self, req: CreateExperiment) -> CreateResponse:
        resp, shard_id, version = self._proxy.create(req)
        with self._lock:
            self._assigned[resp.exp_id] = shard_id
            if req.config:
                self._configs[resp.exp_id] = req.config
        self._refresh_map(version=version)
        return resp

    def suggest(self, exp_id: str, count: int = 1) -> SuggestBatch:
        self.flush()
        batch = self._routed(exp_id, lambda c: c.suggest(exp_id, count))
        if batch.suggestions:
            with self._lock:
                held = self._holdings.setdefault(exp_id, set())
                held.update(s.suggestion_id for s in batch.suggestions)
            # new holdings must reach the manager promptly: a crash in
            # the window before the next periodic beat would otherwise
            # leave these suggestions unknown (and unrecoverable)
            self._wake.set()
        return batch

    def observe(self, req: ObserveRequest) -> ObserveResponse:
        if self._wb is not None:
            # fire-and-forget into the owner's lane; the holding is kept
            # until a flush confirms (see _on_batch_result)
            self._enqueue_op(OP_OBSERVE, req.to_json(), req.exp_id)
            return ObserveResponse(accepted=True, duplicate=False,
                                   observations=-1)
        resp = self._routed(req.exp_id, lambda c: c.observe(req))
        self._drop_holding(req.exp_id, req.suggestion_id)
        return resp

    def report(self, req: ReportRequest) -> Decision:
        if self._wb is not None:
            stashed = self._gate.take_stashed(req)
            if stashed is not None:
                return stashed
            if not self._gate.blocking(req):
                self._enqueue_op(OP_REPORT, req.to_json(), req.exp_id)
                return self._gate.ride_decision(req)
            self._wb.flush()    # ordering: queued ops land first
        d = self._routed(req.exp_id, lambda c: c.report(req))
        if self._gate is not None:
            self._gate.note(self._gate.key(req), d)
            self._gate.take_stashed(req)    # delivered directly: unstash
        return d

    def release(self, exp_id: str, suggestion_id: str) -> bool:
        if self._wb is not None:
            self._enqueue_op(OP_RELEASE,
                             {"exp_id": exp_id,
                              "suggestion_id": suggestion_id}, exp_id)
            return True
        ok = self._routed(exp_id,
                          lambda c: c.release(exp_id, suggestion_id))
        self._drop_holding(exp_id, suggestion_id)
        return ok

    def requeue(self, exp_id: str, suggestion_id: str,
                assignment: Optional[dict] = None) -> bool:
        if self._wb is not None:
            self._enqueue_op(OP_REQUEUE,
                             {"exp_id": exp_id,
                              "suggestion_id": suggestion_id,
                              "assignment": assignment}, exp_id)
            return True
        ok = self._routed(exp_id,
                          lambda c: c.requeue(exp_id, suggestion_id,
                                              assignment=assignment))
        self._drop_holding(exp_id, suggestion_id)
        return ok

    def status(self, exp_id: str) -> StatusResponse:
        self.flush()
        resp = self._routed(exp_id, lambda c: c.status(exp_id))
        if self._wb is not None:
            resp.transport = dict(resp.transport or {})
            resp.transport["batch"] = dict(self._wb.stats)
            resp.transport["batch"]["depth"] = self._wb.depth()
        return resp

    def stop(self, exp_id: str, state: str = "stopped") -> StatusResponse:
        self.flush()
        resp = self._routed(exp_id, lambda c: c.stop(exp_id, state))
        with self._lock:
            self._holdings.pop(exp_id, None)
        return resp

    def best_response(self, exp_id: str) -> BestResponse:
        self.flush()
        return self._routed(exp_id, lambda c: c.best_response(exp_id))

    # -------------------------------------------------------- heartbeats
    def _drop_holding(self, exp_id: str, suggestion_id: str) -> None:
        with self._lock:
            held = self._holdings.get(exp_id)
            if held is not None:
                held.discard(suggestion_id)
                if not held:
                    del self._holdings[exp_id]

    def holdings(self) -> Dict[str, list]:
        with self._lock:
            return {e: sorted(s) for e, s in self._holdings.items()}

    def beat(self) -> HeartbeatResponse:
        """Send one heartbeat now (the daemon thread calls this on its
        own; tests call it to drive liveness deterministically)."""
        if self._fault_plan is not None:
            self._fault_plan.gate(self.worker_id, "manager")
        with self._lock:
            self._seq += 1
            req = HeartbeatRequest(worker_id=self.worker_id,
                                   kind="scheduler",
                                   holdings=self.holdings(), seq=self._seq)
        resp = self._proxy.heartbeat(req)
        with self._lock:
            self._period = max(0.05, float(resp.period))
            self._last_beat = time.monotonic()
        if resp.map_version != self.map_version:
            self._refresh_map(force=True)
        return resp

    def _beat_loop(self) -> None:
        while True:
            self._wake.wait(timeout=self._period)
            self._wake.clear()
            if self._stop.is_set():
                return
            try:
                self.beat()
            except Exception as e:
                # manager briefly unreachable — keep beating (the
                # registry's auto-register tolerates manager restarts),
                # but never silently: the audit trail records it
                self._audit_beat_error(e)

    def _audit_beat_error(self, e: BaseException) -> None:
        """Record a heartbeat failure with bounded dedupe: the first
        occurrence and every 32nd repeat land in ``events``; the rest
        only bump the per-error counter."""
        key = f"{type(e).__name__}: {e}"
        with self._lock:
            n = self._beat_errors.get(key, 0) + 1
            if len(self._beat_errors) >= 32 and key not in self._beat_errors:
                self._beat_errors.pop(next(iter(self._beat_errors)))
            self._beat_errors[key] = n
            if n == 1 or n % 32 == 0:
                self.events.append({"event": "beat_error", "error": key,
                                    "count": n, "time": time.time()})
                if len(self.events) > 128:
                    del self.events[:64]

    def beat_errors(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._beat_errors)

    def close(self, join_timeout: float = 5.0) -> None:
        """Stop the heartbeat thread (joined with a timeout — a beat hung
        in a dead transport must not block interpreter exit) and release
        shard connections."""
        if self._wb is not None:
            try:
                self._wb.close()    # flush queued ops while shards live
            except ApiError:
                pass
        self._stop.set()
        self._wake.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=join_timeout)
            self._hb_thread = None
        self._proxy.close()
