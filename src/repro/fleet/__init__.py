"""Fleet control plane: shard many experiments across N suggestion-service
processes (ROADMAP: "thousands of concurrent experiments").

Pieces:

* :mod:`repro.fleet.hashring`  — consistent-hash experiment→shard routing
* :mod:`repro.fleet.heartbeat` — worker liveness state machine
  (registered → alive → suspect → dead, monotonic-clock deadlines)
* :mod:`repro.fleet.manager`   — FleetManager: shard map + admission
  control + the event loop that detects dead workers/shards and requeues
  their pending suggestions
* :mod:`repro.fleet.router`    — FleetClient: a ``SuggestionClient`` that
  makes the whole fleet look like one service
* :mod:`repro.fleet.serve`     — the manager's HTTP surface +
  ``repro serve-fleet``

See API.md §Fleet for the protocol and failure-mode table.
"""
from repro.fleet.hashring import HashRing
from repro.fleet.heartbeat import (S_ALIVE, S_DEAD, S_REGISTERED, S_SUSPECT,
                                   WorkerRegistry)
from repro.fleet.manager import FleetManager
from repro.fleet.router import FleetClient
from repro.fleet.serve import FleetServer, serve_fleet

__all__ = ["HashRing", "WorkerRegistry", "FleetManager", "FleetClient",
           "FleetServer", "serve_fleet",
           "S_REGISTERED", "S_ALIVE", "S_SUSPECT", "S_DEAD"]
