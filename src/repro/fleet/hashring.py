"""Consistent-hash ring for experiment→shard routing.

Experiments are pinned to shards by hashing the experiment id onto a ring
of virtual nodes (``replicas`` per shard), so adding or removing one shard
moves only ~1/N of the keyspace — the property that makes failover cheap:
when a shard dies, only *its* experiments re-home, everyone else's routes
are untouched.

The hash is ``blake2b`` (stable across processes and Python runs —
``hash()`` is salted per-process and useless for routing agreement).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional


def _h(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(),
                                          digest_size=8).digest(), "big")


class HashRing:
    """Classic consistent hashing with virtual nodes."""

    def __init__(self, nodes: Optional[List[str]] = None, replicas: int = 64):
        self.replicas = max(1, int(replicas))
        self._ring: List[int] = []          # sorted vnode hashes
        self._owner: Dict[int, str] = {}    # vnode hash -> node
        self._nodes: set = set()
        for n in nodes or []:
            self.add(n)

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)

    def add(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for i in range(self.replicas):
            h = _h(f"{node}#{i}")
            # blake2b collisions at 64 bits are ~impossible at fleet
            # sizes; last-add-wins keeps the ring consistent anyway
            if h not in self._owner:
                bisect.insort(self._ring, h)
            self._owner[h] = node

    def remove(self, node: str) -> None:
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        for i in range(self.replicas):
            h = _h(f"{node}#{i}")
            if self._owner.get(h) == node:
                del self._owner[h]
                idx = bisect.bisect_left(self._ring, h)
                if idx < len(self._ring) and self._ring[idx] == h:
                    self._ring.pop(idx)

    def owner(self, key: str) -> Optional[str]:
        """The shard owning ``key`` (clockwise successor vnode)."""
        if not self._ring:
            return None
        h = _h(key)
        idx = bisect.bisect(self._ring, h)
        if idx == len(self._ring):
            idx = 0
        return self._owner[self._ring[idx]]

    def moved_by_adding(self, node: str, keys) -> List[str]:
        """The minimal disruption set: the keys whose ownership would
        move if ``node`` joined the ring.  Consistent hashing guarantees
        a key only ever moves *to* the new node — everyone else's routes
        are untouched — so this is exactly the set a rebalance-on-add
        must hand over.  Non-destructive (simulates the add)."""
        if node in self._nodes or not self._ring:
            return []
        after = HashRing(nodes=list(self._nodes) + [node],
                         replicas=self.replicas)
        return [k for k in keys if after.owner(k) != self.owner(k)]

    def spread(self, keys) -> Dict[str, int]:
        """keys-per-node histogram (balance diagnostics/tests)."""
        out: Dict[str, int] = {n: 0 for n in self._nodes}
        for k in keys:
            o = self.owner(k)
            if o is not None:
                out[o] += 1
        return out
