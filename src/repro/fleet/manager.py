"""FleetManager: the control plane that shards experiments across N
suggestion-service processes.

Responsibilities:

* **Routing truth** — owns the consistent-hash ring and the versioned
  :class:`~repro.api.protocol.ShardMap` (ring ownership + per-experiment
  overrides).  Routers cache the map and re-fetch on a version bump.
* **Admission control** — ``create_experiment`` consults the target
  shard's last load probe (FitExecutor ``backlog`` + ``duty`` cycle, the
  PR 5 signal): a saturated shard's new experiment is redirected to the
  least-loaded eligible shard (recorded as a map override), and when the
  whole fleet is saturated the create comes back as a typed
  ``fleet_busy`` (HTTP 503) the caller can back off on.
* **Liveness event loop** — one thread probes shards (pull: healthz +
  load) and sweeps the worker registry (push: scheduler heartbeats
  carrying their pending-suggestion holdings).  A scheduler declared
  dead gets its leases revoked (``on_dead`` hook) and every pending
  suggestion it held *requeued* on the owning shard — same id, same
  constant-liar lie — so a survivor's next ``suggest`` serves it exactly
  once.  A shard declared dead leaves the ring (version bump); its
  experiments re-home to the ring successor, which adopts them out of
  the shared system-of-record store via a config-less resume (pending
  budget reclaims automatically on replay — the PR 1 restore semantics,
  not a second fault path).

The manager holds no optimizer state and writes nothing but routing
metadata: shards stay the single writers of their experiments' logs.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.api.http import HTTPClient
from repro.api.protocol import (ApiError, CreateExperiment, CreateResponse,
                                E_FLEET_BUSY, E_UNKNOWN_EXPERIMENT,
                                HeartbeatRequest, HeartbeatResponse,
                                ShardMap)
from repro.fleet.hashring import HashRing
from repro.fleet.heartbeat import S_ALIVE, S_DEAD, WorkerRegistry


class ShardHandle:
    """One shard as the manager sees it: an id, a client (HTTP for real
    processes, or any ``SuggestionClient`` with ``load``/``requeue`` for
    in-process shards), and the last probe result."""

    def __init__(self, shard_id: str, client, url: str = ""):
        self.shard_id = shard_id
        self.client = client
        self.url = url
        self.load: Dict[str, Any] = {}      # last successful probe
        self.probe_failures = 0

    def probe(self) -> bool:
        """One liveness+load probe; True on success."""
        try:
            self.load = self.client.load() or {}
            self.probe_failures = 0
            return True
        except Exception:
            self.probe_failures += 1
            return False

    def to_json(self) -> Dict[str, Any]:
        return {"shard_id": self.shard_id, "url": self.url,
                "load": self.load, "probe_failures": self.probe_failures}


class FleetManager:
    """See module docstring.  Thread-safe; ``start()`` spawns the event
    loop, ``stop()`` joins it."""

    #: admission thresholds: a shard is saturated when its fit-executor
    #: backlog or recent duty cycle crosses these
    ADMIT_BACKLOG = 4
    ADMIT_DUTY = 0.75

    def __init__(self, period: float = 1.0,
                 suspect_after: Optional[float] = None,
                 dead_after: Optional[float] = None,
                 admit_backlog: Optional[int] = None,
                 admit_duty: Optional[float] = None,
                 replicas: int = 64):
        self.registry = WorkerRegistry(period=period,
                                       suspect_after=suspect_after,
                                       dead_after=dead_after)
        self.ring = HashRing(replicas=replicas)
        self.admit_backlog = (self.ADMIT_BACKLOG if admit_backlog is None
                              else int(admit_backlog))
        self.admit_duty = (self.ADMIT_DUTY if admit_duty is None
                           else float(admit_duty))
        self._lock = threading.RLock()
        self._shards: Dict[str, ShardHandle] = {}
        self._overrides: Dict[str, str] = {}     # exp_id -> shard_id
        self._experiments: Dict[str, str] = {}   # exp_id -> shard_id (last)
        self._version = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events: List[Dict[str, Any]] = []   # bounded audit trail
        self.stats = {"ticks": 0, "requeued": 0, "dead_workers": 0,
                      "dead_shards": 0, "redirects": 0, "busy_rejections": 0,
                      "adopted": 0}

    # ----------------------------------------------------------- membership
    def add_shard(self, url_or_client, shard_id: Optional[str] = None
                  ) -> ShardHandle:
        """Attach one shard (a ``repro serve-api`` URL, or an in-process
        client).  Bumps the map version."""
        if isinstance(url_or_client, str):
            url = url_or_client.rstrip("/")
            client = HTTPClient(url, timeout=5.0)
            shard_id = shard_id or url
        else:
            client = url_or_client
            url = getattr(client, "base_url", "")
            shard_id = shard_id or f"shard-{len(self._shards)}"
        handle = ShardHandle(shard_id, client, url)
        with self._lock:
            self._shards[shard_id] = handle
            self.ring.add(shard_id)
            self._version += 1
        self.registry.register(shard_id, kind="shard", url=url)
        return handle

    def remove_shard(self, shard_id: str) -> None:
        """Administrative removal (drain); dead shards go through
        ``_on_dead_shard`` instead."""
        with self._lock:
            self._shards.pop(shard_id, None)
            self.ring.remove(shard_id)
            self._purge_overrides(shard_id)
            self._version += 1

    def _purge_overrides(self, shard_id: str) -> None:
        # holding self._lock
        for exp, sid in list(self._overrides.items()):
            if sid == shard_id:
                del self._overrides[exp]

    # -------------------------------------------------------------- routing
    def shard_map(self) -> ShardMap:
        with self._lock:
            return ShardMap(version=self._version,
                            shards={s.shard_id: s.url
                                    for s in self._shards.values()},
                            overrides=dict(self._overrides))

    def owner_of(self, exp_id: str) -> Optional[ShardHandle]:
        with self._lock:
            sid = self._overrides.get(exp_id) or self.ring.owner(exp_id)
            return self._shards.get(sid) if sid else None

    def _eligible(self) -> List[ShardHandle]:
        """Alive shards, least-loaded first (backlog, duty, live count)."""
        out = []
        with self._lock:
            shards = list(self._shards.values())
        for s in shards:
            if self.registry.state(s.shard_id) in (S_ALIVE, None) \
                    or self.registry.state(s.shard_id) == "registered":
                out.append(s)
        out.sort(key=lambda s: (int(s.load.get("backlog", 0)),
                                float(s.load.get("duty", 0.0)),
                                int(s.load.get("live", 0))))
        return out

    def _saturated(self, shard: ShardHandle) -> bool:
        return (int(shard.load.get("backlog", 0)) >= self.admit_backlog
                or float(shard.load.get("duty", 0.0)) >= self.admit_duty)

    # ------------------------------------------------------------ admission
    def create_experiment(self, req: CreateExperiment
                          ) -> Tuple[CreateResponse, str, str, int]:
        """Admission-controlled create: route to the hash owner unless it
        is saturated, else redirect to the least-loaded eligible shard
        (recorded as a map override); raise ``fleet_busy`` when every
        shard is saturated.  Returns (response, shard_id, url, version)."""
        exp_id = req.exp_id
        if exp_id is None:
            from repro.core.experiment import new_experiment_id
            exp_id = new_experiment_id()
            req = CreateExperiment(config=req.config, exp_id=exp_id)
        target = self.owner_of(exp_id)
        if target is None:
            raise ApiError(E_FLEET_BUSY, "fleet has no shards")
        if self._saturated(target):
            eligible = [s for s in self._eligible()
                        if not self._saturated(s)]
            if not eligible:
                with self._lock:
                    self.stats["busy_rejections"] += 1
                raise ApiError(
                    E_FLEET_BUSY,
                    f"all {len(self._shards)} shards saturated "
                    f"(backlog>={self.admit_backlog} or "
                    f"duty>={self.admit_duty}); retry later")
            redirect = eligible[0]
            with self._lock:
                if redirect.shard_id != self.ring.owner(exp_id):
                    self._overrides[exp_id] = redirect.shard_id
                else:
                    self._overrides.pop(exp_id, None)
                self._version += 1
                self.stats["redirects"] += 1
            self._event("admission_redirect", exp_id=exp_id,
                        from_shard=target.shard_id,
                        to_shard=redirect.shard_id)
            target = redirect
        resp = target.client.create_experiment(req)
        with self._lock:
            self._experiments[resp.exp_id] = target.shard_id
            version = self._version
        return resp, target.shard_id, target.url, version

    # ------------------------------------------------------------ liveness
    def heartbeat(self, req: HeartbeatRequest,
                  on_dead: Optional[Callable] = None) -> HeartbeatResponse:
        state = self.registry.beat(req.worker_id, kind=req.kind,
                                   holdings=req.holdings)
        if on_dead is not None:
            rec = self.registry.get(req.worker_id)
            if rec is not None:
                rec.on_dead = on_dead
        with self._lock:
            version = self._version
        return HeartbeatResponse(state=state, map_version=version,
                                 period=self.registry.period)

    def start(self) -> "FleetManager":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._run,
                                            name="fleet-manager",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join and self._thread is not None \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout=10)

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive() \
            and not self._stop.is_set()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception as e:  # noqa: the loop must survive any tick
                self._event("tick_error", error=f"{type(e).__name__}: {e}")
            self._stop.wait(self.registry.period)

    def tick(self) -> None:
        """One event-loop round: probe shards in parallel, sweep the
        registry, and act on every freshly-dead worker.  Public so tests
        (and a paused manager) can drive the loop deterministically."""
        with self._lock:
            shards = list(self._shards.values())
            self.stats["ticks"] += 1
        threads = [threading.Thread(target=self._probe_one, args=(s,),
                                    daemon=True) for s in shards]
        for t in threads:
            t.start()
        for t in threads:
            # a wedged shard must not stall the loop past ~one period
            t.join(timeout=max(0.2, self.registry.period))
        for rec in self.registry.sweep():
            if rec.kind == "shard":
                handle = self._shards.get(rec.worker_id)
                if handle is not None and handle.probe_failures == 0:
                    # silent past the deadline but no probe ever *failed*:
                    # the shard is slow (startup, GC, load), not gone —
                    # only refused/broken connections count as shard death
                    self.registry.beat(rec.worker_id, kind="shard",
                                       url=rec.url)
                    continue
                self._on_dead_shard(rec.worker_id)
            else:
                self._on_dead_worker(rec)

    def _probe_one(self, shard: ShardHandle) -> None:
        if shard.probe():
            self.registry.beat(shard.shard_id, kind="shard", url=shard.url)

    # --------------------------------------------------------- fault paths
    def _on_dead_worker(self, rec) -> None:
        """A scheduler stopped heartbeating: revoke its leases (hook) and
        requeue every pending suggestion it held so survivors can claim
        them.  Requeue (not release) keeps id + lie — the observation,
        whoever finally produces it, dedupes service-side."""
        with self._lock:
            self.stats["dead_workers"] += 1
        if rec.on_dead is not None:
            try:
                rec.on_dead(rec)
            except Exception:
                pass
        requeued = 0
        for exp_id, sids in rec.holdings.items():
            shard = self.owner_of(exp_id)
            if shard is None:
                continue
            for sid in sids:
                try:
                    if shard.client.requeue(exp_id, sid):
                        requeued += 1
                except ApiError:
                    pass        # experiment gone / shard mid-failover
        with self._lock:
            self.stats["requeued"] += requeued
        self._event("worker_dead", worker_id=rec.worker_id,
                    requeued=requeued)

    def _on_dead_shard(self, shard_id: str) -> None:
        """A shard stopped answering probes: drop it from the ring (map
        version bump) and re-home its experiments to their new ring
        owners via config-less resume from the shared store.  The dead
        shard's in-memory pending set is gone; the resume replay reclaims
        that budget (PR 1 restore semantics)."""
        with self._lock:
            self.stats["dead_shards"] += 1
            dead = self._shards.pop(shard_id, None)
            self.ring.remove(shard_id)
            self._purge_overrides(shard_id)
            self._version += 1
            orphans = [e for e, s in self._experiments.items()
                       if s == shard_id]
        adopted = 0
        for exp_id in orphans:
            new_owner = self.owner_of(exp_id)
            if new_owner is None:
                continue
            try:
                new_owner.client.create_experiment(
                    CreateExperiment(config={}, exp_id=exp_id))
                adopted += 1
                with self._lock:
                    self._experiments[exp_id] = new_owner.shard_id
            except ApiError as e:
                # store not shared with this shard (or experiment never
                # persisted): routers with the config cached will re-home
                # it on their next create
                if e.code != E_UNKNOWN_EXPERIMENT:
                    self._event("adopt_failed", exp_id=exp_id,
                                error=str(e))
            except Exception as e:
                self._event("adopt_failed", exp_id=exp_id, error=str(e))
        with self._lock:
            self.stats["adopted"] += adopted
        self._event("shard_dead", shard_id=shard_id,
                    url=dead.url if dead else "", orphans=len(orphans),
                    adopted=adopted)

    # --------------------------------------------------------------- misc
    def _event(self, kind: str, **fields) -> None:
        with self._lock:
            self.events.append(dict(fields, event=kind))
            if len(self.events) > 256:
                del self.events[:128]

    def status(self) -> Dict[str, Any]:
        with self._lock:
            shards = {s.shard_id: s.to_json()
                      for s in self._shards.values()}
            version = self._version
            stats = dict(self.stats)
            experiments = len(self._experiments)
        return {"version": version, "shards": shards,
                "workers": self.registry.to_json(),
                "experiments": experiments, "stats": stats,
                "period": self.registry.period}
