"""FleetManager: the control plane that shards experiments across N
suggestion-service processes.

Responsibilities:

* **Routing truth** — owns the consistent-hash ring and the versioned
  :class:`~repro.api.protocol.ShardMap` (ring ownership + per-experiment
  overrides).  Routers cache the map and re-fetch on a version bump.
* **Admission control** — ``create_experiment`` consults the target
  shard's last load probe (FitExecutor ``backlog`` + ``duty`` cycle, the
  PR 5 signal): a saturated shard's new experiment is redirected to the
  least-loaded eligible shard (recorded as a map override), and when the
  whole fleet is saturated the create comes back as a typed
  ``fleet_busy`` (HTTP 503) the caller can back off on.
* **Ownership epochs (fencing)** — every create/adoption/handover the
  manager initiates carries a granted ``[term, seq]`` epoch; the adopting
  shard claims the experiment's fence record at that epoch, so every
  older incarnation (a zombie across a healed partition, a loser of a
  dual-manager split) is actively rejected at its next durable write
  with ``E_FENCED`` instead of silently splitting the log.
* **Liveness event loop** — one thread probes shards (pull: healthz +
  load, each probe bounded by a per-probe deadline) and sweeps the
  worker registry (push: scheduler heartbeats carrying their
  pending-suggestion holdings).  A scheduler declared dead gets its
  leases revoked (``on_dead`` hook) and every pending suggestion it held
  *requeued* on the owning shard — same id, same constant-liar lie — so
  a survivor's next ``suggest`` serves it exactly once.  A shard
  declared dead leaves the ring (version bump); its experiments re-home
  to the ring successor, which adopts them out of the shared
  system-of-record store at a freshly granted epoch.
* **Rebalance on add** — a shard joining the ring receives exactly the
  experiments whose ring ownership moved (minimal key disruption):
  each is *drained* on its current owner (pump stopped, pendings
  parked), adopted by the new owner at a bumped epoch (fencing the old
  one), and its parked pendings transferred under their original ids.
  A crash-safe handover journal (``fleet/rebalance.json``) lets a
  manager death mid-rebalance resume — or roll back — cleanly.
* **Warm standby** — a second manager constructed with ``standby=True``
  watches the epoch-guarded leader lease in the shared store; on a
  stale lease it rebuilds registry + ring + overrides from the control
  snapshot and heartbeat event tail, bumps the leadership *term* (so
  all its epoch grants out-rank the old manager's), resumes any
  in-flight rebalance journal, and starts acting.  Fencing makes
  split-brain harmless: the deposed manager's grants lose every claim.

The manager holds no optimizer state; besides routing metadata it writes
only the ``fleet/`` control files (leader lease, rebuildable snapshot,
event tail, rebalance journal) — shards stay the single writers of their
experiments' logs.
"""
from __future__ import annotations

import json
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.api.http import HTTPClient
from repro.api.protocol import (ApiError, CreateExperiment, CreateResponse,
                                E_FENCED, E_FLEET_BUSY,
                                E_UNKNOWN_EXPERIMENT, HeartbeatRequest,
                                HeartbeatResponse, ShardMap)
from repro.core.store import Store
from repro.fleet.hashring import HashRing
from repro.fleet.heartbeat import S_ALIVE, S_DEAD, WorkerRegistry


class ShardHandle:
    """One shard as the manager sees it: an id, a client (HTTP for real
    processes, or any ``SuggestionClient`` with ``load``/``requeue`` for
    in-process shards), and the last probe result."""

    def __init__(self, shard_id: str, client, url: str = ""):
        self.shard_id = shard_id
        self.client = client
        self.url = url
        self.load: Dict[str, Any] = {}      # last successful probe
        self.probe_failures = 0
        self.probe_timeouts = 0
        # chaos harness: manager↔shard edge gate (raises InjectedPartition)
        self.fault_gate: Optional[Callable[[], None]] = None

    def gate(self) -> None:
        if self.fault_gate is not None:
            self.fault_gate()

    def probe(self) -> bool:
        """One liveness+load probe; True on success."""
        try:
            self.gate()
            load = self.client.load() or {}
        except Exception:
            self.probe_failures += 1
            return False
        self.load = load
        self.probe_failures = 0
        return True

    def note_timeout(self) -> None:
        """The event loop's per-probe deadline expired with this probe
        still in flight: count it as a failed probe (no beat this tick)
        so a wedged shard — accepting connections but never answering —
        still progresses toward ``dead`` instead of stalling the tick."""
        self.probe_failures += 1
        self.probe_timeouts += 1

    def to_json(self) -> Dict[str, Any]:
        return {"shard_id": self.shard_id, "url": self.url,
                "load": self.load, "probe_failures": self.probe_failures,
                "probe_timeouts": self.probe_timeouts}


class FleetManager:
    """See module docstring.  Thread-safe; ``start()`` spawns the event
    loop, ``stop()`` joins it."""

    #: admission thresholds: a shard is saturated when its fit-executor
    #: backlog or recent duty cycle crosses these
    ADMIT_BACKLOG = 4
    ADMIT_DUTY = 0.75

    def __init__(self, period: float = 1.0,
                 suspect_after: Optional[float] = None,
                 dead_after: Optional[float] = None,
                 admit_backlog: Optional[int] = None,
                 admit_duty: Optional[float] = None,
                 replicas: int = 64,
                 store: Optional[Union[Store, str]] = None,
                 manager_id: Optional[str] = None,
                 standby: bool = False,
                 probe_timeout: Optional[float] = None,
                 lease_timeout: Optional[float] = None,
                 shard_resolver: Optional[Callable] = None,
                 fault_plan=None):
        self.registry = WorkerRegistry(period=period,
                                       suspect_after=suspect_after,
                                       dead_after=dead_after)
        self.ring = HashRing(replicas=replicas)
        self.admit_backlog = (self.ADMIT_BACKLOG if admit_backlog is None
                              else int(admit_backlog))
        self.admit_duty = (self.ADMIT_DUTY if admit_duty is None
                           else float(admit_duty))
        self.store = (store if (store is None or isinstance(store, Store))
                      else Store(store))
        self.manager_id = manager_id or f"mgr-{uuid.uuid4().hex[:6]}"
        # per-probe deadline (ISSUE 7 satellite): the tick budgets this
        # much wall clock for the WHOLE parallel probe round; a probe
        # still in flight past it is counted failed for this tick
        self.probe_timeout = (max(0.2, period) if probe_timeout is None
                              else float(probe_timeout))
        self.lease_timeout = (3.0 * period if lease_timeout is None
                              else float(lease_timeout))
        # standby: rebuilds in-proc shard clients on takeover;
        # (shard_id, url) -> client, defaults to HTTPClient(url)
        self._shard_resolver = shard_resolver
        self.fault_plan = fault_plan
        self._lock = threading.RLock()
        self._shards: Dict[str, ShardHandle] = {}
        self._overrides: Dict[str, str] = {}     # exp_id -> shard_id
        self._experiments: Dict[str, str] = {}   # exp_id -> shard_id (last)
        self._version = 0
        self._epoch_seq = 0                      # monotone grant counter
        self._logged_holdings: Dict[str, str] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events: List[Dict[str, Any]] = []   # bounded audit trail
        self.stats = {"ticks": 0, "requeued": 0, "dead_workers": 0,
                      "dead_shards": 0, "redirects": 0, "busy_rejections": 0,
                      "adopted": 0, "rebalanced": 0, "probe_timeouts": 0}
        self.term = 0
        self.role = "standby" if standby else "active"
        if not standby:
            self._become_leader()
            self._resume_rebalance()

    # ----------------------------------------------------------- leadership
    def _become_leader(self) -> None:
        """Claim (or re-claim) leadership: term = stored term + 1, so
        every epoch this manager grants out-ranks every grant of every
        previous leader — the fencing layer does the rest."""
        prev = 0
        if self.store is not None:
            rec = self.store.read_fleet_state("leader") or {}
            prev = int(rec.get("term", 0))
        self.term = max(self.term, prev) + 1
        self.role = "active"
        self._renew_lease()

    def _renew_lease(self) -> bool:
        """Refresh the epoch-guarded leader file; detect deposition.  A
        newer term in the file means another manager took over — stand
        down (our grants lose every fence claim anyway)."""
        if self.store is None:
            return True
        rec = self.store.read_fleet_state("leader") or {}
        if int(rec.get("term", 0)) > self.term:
            self.role = "deposed"
            self._event("deposed", term=self.term,
                        by_term=int(rec.get("term", 0)),
                        by=rec.get("manager_id", ""))
            return False
        self.store.write_fleet_state("leader", {
            "manager_id": self.manager_id, "term": self.term,
            "time": time.time(), "period": self.registry.period})
        return True

    def _grant_epoch(self) -> List[int]:
        with self._lock:
            self._epoch_seq += 1
            return [self.term, self._epoch_seq]

    def _persist(self) -> None:
        """Write the rebuildable control snapshot (standby's cold-start
        state).  Called on every membership / override / ownership
        change — the manager is off the suggest/observe hot path, so
        this is one small atomic file write per rare control event."""
        if self.store is None or self.role != "active":
            return
        with self._lock:
            snap = {"manager_id": self.manager_id, "term": self.term,
                    "version": self._version, "epoch_seq": self._epoch_seq,
                    "period": self.registry.period,
                    "shards": {sid: h.url
                               for sid, h in self._shards.items()},
                    "overrides": dict(self._overrides),
                    "experiments": dict(self._experiments),
                    "time": time.time()}
        self.store.write_fleet_state("manager", snap)

    # ------------------------------------------------------------- standby
    def poll_standby(self) -> bool:
        """One standby round: watch the active's lease, take over when it
        goes stale (or vanishes).  Public so tests drive failover
        deterministically.  Returns True when a takeover happened."""
        if self.store is None or self.role != "standby":
            return False
        rec = self.store.read_fleet_state("leader")
        if rec is not None:
            self.term = max(self.term, int(rec.get("term", 0)))
            age = time.time() - float(rec.get("time", 0.0))
            if age <= self.lease_timeout:
                return False
        self.takeover()
        return True

    def takeover(self) -> None:
        """Standby → active: rebuild registry + ring + overrides from the
        control snapshot and the heartbeat event tail, bump the
        leadership term (stale grants now lose every claim), resume or
        roll back an in-flight rebalance journal, and start acting."""
        snap = (self.store.read_fleet_state("manager") or {}
                if self.store is not None else {})
        with self._lock:
            self._version = max(self._version, int(snap.get("version", 0)))
            self._epoch_seq = max(self._epoch_seq,
                                  int(snap.get("epoch_seq", 0)))
            for exp, sid in (snap.get("overrides") or {}).items():
                self._overrides.setdefault(exp, sid)
            for exp, sid in (snap.get("experiments") or {}).items():
                self._experiments.setdefault(exp, sid)
        if float(snap.get("period", 0)) > 0:
            self.registry.period = float(snap["period"])
        for sid, url in (snap.get("shards") or {}).items():
            with self._lock:
                known = sid in self._shards
            if known:
                continue
            client = None
            if self._shard_resolver is not None:
                client = self._shard_resolver(sid, url)
            elif url:
                client = HTTPClient(url, timeout=5.0)
            if client is None:
                continue
            self._install_shard(ShardHandle(sid, client, url))
        # replay worker holdings from the event tail so a death right
        # after takeover still requeues the right suggestions
        if self.store is not None:
            for ev in self.store.load_fleet_events():
                if ev.get("event") == "beat":
                    self.registry.beat(ev.get("worker_id", ""),
                                       kind=ev.get("kind", "scheduler"),
                                       holdings=ev.get("holdings") or {})
        self._become_leader()
        with self._lock:
            self._version += 1      # force routers to re-fetch from us
        self._event("takeover", manager_id=self.manager_id, term=self.term)
        self._resume_rebalance()
        self._persist()

    # ----------------------------------------------------------- membership
    def _install_shard(self, handle: ShardHandle) -> None:
        if self.fault_plan is not None:
            handle.fault_gate = self.fault_plan.edge_gate(
                "manager", handle.shard_id)
        with self._lock:
            self._shards[handle.shard_id] = handle
            self.ring.add(handle.shard_id)
            self._version += 1
        self.registry.register(handle.shard_id, kind="shard",
                               url=handle.url)

    def add_shard(self, url_or_client, shard_id: Optional[str] = None,
                  rebalance: bool = True) -> ShardHandle:
        """Attach one shard (a ``repro serve-api`` URL, or an in-process
        client).  Bumps the map version and — unless ``rebalance=False``
        — hands over exactly the experiments whose ring ownership moved
        to the new shard (minimal disruption set), via the crash-safe
        drain → adopt(epoch bump) → transfer journal."""
        if isinstance(url_or_client, str):
            url = url_or_client.rstrip("/")
            client = HTTPClient(url, timeout=5.0)
            shard_id = shard_id or url
        else:
            client = url_or_client
            url = getattr(client, "base_url", "")
            shard_id = shard_id or f"shard-{len(self._shards)}"
        handle = ShardHandle(shard_id, client, url)
        moved: List[str] = []
        with self._lock:
            if rebalance:
                moved = self.ring.moved_by_adding(
                    shard_id, [e for e in self._experiments
                               if e not in self._overrides])
        self._install_shard(handle)
        self._persist()
        if moved:
            self._rebalance(moved, shard_id)
        return handle

    def remove_shard(self, shard_id: str) -> None:
        """Administrative removal (drain); dead shards go through
        ``_on_dead_shard`` instead."""
        with self._lock:
            self._shards.pop(shard_id, None)
            self.ring.remove(shard_id)
            self._purge_overrides(shard_id)
            self._version += 1
        self._persist()

    def _purge_overrides(self, shard_id: str) -> None:
        # holding self._lock
        for exp, sid in list(self._overrides.items()):
            if sid == shard_id:
                del self._overrides[exp]

    # ------------------------------------------------------------ rebalance
    def _rebalance(self, moved: List[str], new_sid: str) -> None:
        """Build + journal + run the handover plan for ``moved``."""
        with self._lock:
            entries = [{"exp_id": e,
                        "from": self._experiments.get(e, ""),
                        "epoch": self._grant_epoch(), "done": False}
                       for e in sorted(moved)]
        journal = {"id": uuid.uuid4().hex[:8], "to": new_sid,
                   "term": self.term, "time": time.time(),
                   "entries": entries}
        if self.store is not None:
            self.store.write_fleet_state("rebalance", journal)
        self._event("rebalance_begin", to=new_sid, moved=len(entries))
        self._run_journal(journal)

    def _resume_rebalance(self) -> None:
        """Crash recovery: a journal on disk means a manager died (or was
        deposed) mid-rebalance.  Re-grant the undone entries at OUR term
        — the dead manager's grants may already be contested — and run
        the journal to completion; a vanished target shard rolls the
        whole thing back instead."""
        if self.store is None:
            return
        journal = self.store.read_fleet_state("rebalance")
        if not journal:
            return
        remaining = [e for e in journal.get("entries", [])
                     if not e.get("done")]
        for entry in remaining:
            entry["epoch"] = self._grant_epoch()
        self.store.write_fleet_state("rebalance", journal)
        self._event("rebalance_resume", to=journal.get("to", ""),
                    remaining=len(remaining))
        self._run_journal(journal)

    def _run_journal(self, journal: Dict[str, Any]) -> None:
        new_sid = journal.get("to", "")
        with self._lock:
            target = self._shards.get(new_sid)
        if target is None:
            # target left (or never re-joined after the crash): roll back
            # — the ring no longer routes to it, experiments stay where
            # they are, nothing was half-moved (entries are atomic)
            if self.store is not None:
                self.store.clear_fleet_state("rebalance")
            self._event("rebalance_rollback", to=new_sid)
            return
        for entry in journal.get("entries", []):
            if entry.get("done"):
                continue
            if self._handover(entry, target):
                entry["done"] = True
                with self._lock:
                    self.stats["rebalanced"] += 1
                if self.store is not None:
                    # journal the per-entry progress so a crash between
                    # entries resumes exactly where it stopped
                    self.store.write_fleet_state("rebalance", journal)
        if all(e.get("done") for e in journal.get("entries", [])):
            if self.store is not None:
                self.store.clear_fleet_state("rebalance")
            self._persist()
            self._event("rebalance_done", to=new_sid,
                        moved=len(journal.get("entries", [])))

    def _handover(self, entry: Dict[str, Any], target: ShardHandle) -> bool:
        """Move one experiment: drain on the old owner (park pendings),
        adopt on the new owner at the granted epoch (fences the old
        incarnation), transfer the parked pendings under their original
        ids.  Returns True when the entry is settled (including the
        benign nothing-to-do outcomes)."""
        exp_id, old_sid = entry["exp_id"], entry.get("from", "")
        with self._lock:
            old = self._shards.get(old_sid)
        pending = []
        if old is not None and old_sid != target.shard_id:
            try:
                old.gate()
                dr = old.client.drain(exp_id)
                pending = dr.pending
            except Exception as e:
                # old owner unreachable: adopt anyway — its incarnation
                # is fenced the moment the claim lands, and its pendings
                # requeue via the worker-death path if their holders die
                self._event("drain_failed", exp_id=exp_id,
                            from_shard=old_sid, error=str(e))
        try:
            target.gate()
            target.client.create_experiment(CreateExperiment(
                config={}, exp_id=exp_id, epoch=entry["epoch"]))
        except ApiError as e:
            if e.code == E_UNKNOWN_EXPERIMENT:
                # store not shared / experiment never persisted: routers
                # holding the config re-home it on their next call
                self._event("handover_skipped", exp_id=exp_id,
                            error=str(e))
                return True
            if e.code == E_FENCED:
                # someone out-granted us mid-handover (we were deposed):
                # the experiment already has a newer owner — settled
                self._event("handover_fenced", exp_id=exp_id)
                return True
            self._event("adopt_failed", exp_id=exp_id, error=str(e))
            return False
        except Exception as e:
            self._event("adopt_failed", exp_id=exp_id, error=str(e))
            return False
        transferred = 0
        for s in pending:
            try:
                if target.client.requeue(exp_id, s.suggestion_id,
                                         assignment=s.assignment):
                    transferred += 1
            except Exception:
                pass    # already observed / experiment stopped
        with self._lock:
            self._experiments[exp_id] = target.shard_id
        self._event("handover", exp_id=exp_id, from_shard=old_sid,
                    to_shard=target.shard_id, epoch=entry["epoch"],
                    transferred=transferred)
        return True

    # -------------------------------------------------------------- routing
    def shard_map(self) -> ShardMap:
        with self._lock:
            return ShardMap(version=self._version,
                            shards={s.shard_id: s.url
                                    for s in self._shards.values()},
                            overrides=dict(self._overrides))

    def owner_of(self, exp_id: str) -> Optional[ShardHandle]:
        with self._lock:
            sid = self._overrides.get(exp_id) or self.ring.owner(exp_id)
            return self._shards.get(sid) if sid else None

    def _eligible(self) -> List[ShardHandle]:
        """Alive shards, least-loaded first (backlog, duty, live count)."""
        out = []
        with self._lock:
            shards = list(self._shards.values())
        for s in shards:
            if self.registry.state(s.shard_id) in (S_ALIVE, None) \
                    or self.registry.state(s.shard_id) == "registered":
                out.append(s)
        out.sort(key=lambda s: (int(s.load.get("backlog", 0)),
                                float(s.load.get("duty", 0.0)),
                                int(s.load.get("live", 0))))
        return out

    def _saturated(self, shard: ShardHandle) -> bool:
        return (int(shard.load.get("backlog", 0)) >= self.admit_backlog
                or float(shard.load.get("duty", 0.0)) >= self.admit_duty)

    # ------------------------------------------------------------ admission
    def create_experiment(self, req: CreateExperiment
                          ) -> Tuple[CreateResponse, str, str, int]:
        """Admission-controlled create: route to the hash owner unless it
        is saturated, else redirect to the least-loaded eligible shard
        (recorded as a map override); raise ``fleet_busy`` when every
        shard is saturated.  The create is forwarded with a granted
        ownership epoch — the serving shard claims the experiment's
        fence record at it.  Returns (response, shard_id, url, version)."""
        exp_id = req.exp_id
        if exp_id is None:
            from repro.core.experiment import new_experiment_id
            exp_id = new_experiment_id()
        req = CreateExperiment(config=req.config, exp_id=exp_id,
                               epoch=self._grant_epoch())
        target = self.owner_of(exp_id)
        if target is None:
            raise ApiError(E_FLEET_BUSY, "fleet has no shards")
        if self._saturated(target):
            eligible = [s for s in self._eligible()
                        if not self._saturated(s)]
            if not eligible:
                with self._lock:
                    self.stats["busy_rejections"] += 1
                raise ApiError(
                    E_FLEET_BUSY,
                    f"all {len(self._shards)} shards saturated "
                    f"(backlog>={self.admit_backlog} or "
                    f"duty>={self.admit_duty}); retry later")
            redirect = eligible[0]
            with self._lock:
                if redirect.shard_id != self.ring.owner(exp_id):
                    self._overrides[exp_id] = redirect.shard_id
                else:
                    self._overrides.pop(exp_id, None)
                self._version += 1
                self.stats["redirects"] += 1
            self._event("admission_redirect", exp_id=exp_id,
                        from_shard=target.shard_id,
                        to_shard=redirect.shard_id)
            target = redirect
        resp = target.client.create_experiment(req)
        with self._lock:
            self._experiments[resp.exp_id] = target.shard_id
            version = self._version
        self._persist()
        return resp, target.shard_id, target.url, version

    # ------------------------------------------------------------ liveness
    def heartbeat(self, req: HeartbeatRequest,
                  on_dead: Optional[Callable] = None) -> HeartbeatResponse:
        state = self.registry.beat(req.worker_id, kind=req.kind,
                                   holdings=req.holdings)
        if on_dead is not None:
            rec = self.registry.get(req.worker_id)
            if rec is not None:
                rec.on_dead = on_dead
        with self._lock:
            version = self._version
        # persist holdings *changes* to the event tail: that's exactly
        # what a standby needs to requeue correctly after takeover
        if self.store is not None and self.role == "active":
            key = json.dumps(req.holdings, sort_keys=True)
            with self._lock:
                changed = self._logged_holdings.get(req.worker_id) != key
                if changed:
                    self._logged_holdings[req.worker_id] = key
            if changed:
                self.store.append_fleet_event(
                    {"event": "beat", "worker_id": req.worker_id,
                     "kind": req.kind, "holdings": req.holdings,
                     "time": time.time()})
        return HeartbeatResponse(state=state, map_version=version,
                                 period=self.registry.period)

    def start(self) -> "FleetManager":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._run,
                                            name="fleet-manager",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join and self._thread is not None \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout=10)

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive() \
            and not self._stop.is_set()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                if self.role == "standby":
                    self.poll_standby()
                elif self.role == "active":
                    self.tick()
                else:           # deposed: nothing left to do
                    return
            except Exception as e:  # noqa: the loop must survive any tick
                self._event("tick_error", error=f"{type(e).__name__}: {e}")
            self._stop.wait(self.registry.period)

    def tick(self) -> None:
        """One event-loop round: renew the leader lease, probe shards in
        parallel (per-probe deadline), sweep the registry, and act on
        every freshly-dead worker.  Public so tests (and a paused
        manager) can drive the loop deterministically."""
        if self.fault_plan is not None:
            self.fault_plan.tick()      # the chaos harness's logical clock
        if self.role != "active" or not self._renew_lease():
            return
        with self._lock:
            shards = list(self._shards.values())
            self.stats["ticks"] += 1
        deadline = time.monotonic() + self.probe_timeout
        threads = [threading.Thread(target=self._probe_one, args=(s,),
                                    daemon=True) for s in shards]
        for t in threads:
            t.start()
        for s, t in zip(shards, threads):
            # ONE shared deadline for the round: a single wedged shard
            # consumes its own budget, not one timeout per shard — the
            # old sequential join let N hung probes stall the tick N
            # periods, delaying dead-worker detection fleet-wide
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                s.note_timeout()
                with self._lock:
                    self.stats["probe_timeouts"] += 1
        for rec in self.registry.sweep():
            if rec.kind == "shard":
                handle = self._shards.get(rec.worker_id)
                if handle is not None and handle.probe_failures == 0:
                    # silent past the deadline but no probe ever *failed*:
                    # the shard is slow (startup, GC, load), not gone —
                    # only refused/broken/timed-out probes count as death
                    self.registry.beat(rec.worker_id, kind="shard",
                                       url=rec.url)
                    continue
                self._on_dead_shard(rec.worker_id)
            else:
                self._on_dead_worker(rec)

    def _probe_one(self, shard: ShardHandle) -> None:
        if shard.probe():
            self.registry.beat(shard.shard_id, kind="shard", url=shard.url)

    # --------------------------------------------------------- fault paths
    def _on_dead_worker(self, rec) -> None:
        """A scheduler stopped heartbeating: revoke its leases (hook) and
        requeue every pending suggestion it held so survivors can claim
        them.  Requeue (not release) keeps id + lie — the observation,
        whoever finally produces it, dedupes service-side."""
        with self._lock:
            self.stats["dead_workers"] += 1
        if rec.on_dead is not None:
            try:
                rec.on_dead(rec)
            except Exception:
                pass
        requeued = 0
        for exp_id, sids in rec.holdings.items():
            shard = self.owner_of(exp_id)
            if shard is None:
                continue
            for sid in sids:
                try:
                    shard.gate()
                    if shard.client.requeue(exp_id, sid):
                        requeued += 1
                except (ApiError, ConnectionError):
                    pass        # experiment gone / shard mid-failover
        with self._lock:
            self.stats["requeued"] += requeued
        self._event("worker_dead", worker_id=rec.worker_id,
                    requeued=requeued)

    def _on_dead_shard(self, shard_id: str) -> None:
        """A shard stopped answering probes: drop it from the ring (map
        version bump) and re-home its experiments to their new ring
        owners — each adopted out of the shared system-of-record store
        at a freshly granted epoch, so if the 'dead' shard was merely
        partitioned it comes back to find every write fenced.  The dead
        shard's in-memory pending set is gone; the resume replay
        reclaims that budget (PR 1 restore semantics)."""
        with self._lock:
            self.stats["dead_shards"] += 1
            dead = self._shards.pop(shard_id, None)
            self.ring.remove(shard_id)
            self._purge_overrides(shard_id)
            self._version += 1
            orphans = [e for e, s in self._experiments.items()
                       if s == shard_id]
        adopted = 0
        for exp_id in orphans:
            new_owner = self.owner_of(exp_id)
            if new_owner is None:
                continue
            try:
                new_owner.gate()
                new_owner.client.create_experiment(
                    CreateExperiment(config={}, exp_id=exp_id,
                                     epoch=self._grant_epoch()))
                adopted += 1
                with self._lock:
                    self._experiments[exp_id] = new_owner.shard_id
            except ApiError as e:
                # store not shared with this shard (or experiment never
                # persisted): routers with the config cached will re-home
                # it on their next create
                if e.code != E_UNKNOWN_EXPERIMENT:
                    self._event("adopt_failed", exp_id=exp_id,
                                error=str(e))
            except Exception as e:
                self._event("adopt_failed", exp_id=exp_id, error=str(e))
        with self._lock:
            self.stats["adopted"] += adopted
        self._persist()
        self._event("shard_dead", shard_id=shard_id,
                    url=dead.url if dead else "", orphans=len(orphans),
                    adopted=adopted)

    # --------------------------------------------------------------- misc
    def _event(self, kind: str, **fields) -> None:
        with self._lock:
            self.events.append(dict(fields, event=kind))
            if len(self.events) > 256:
                del self.events[:128]
        # lifecycle events land in the durable audit tail too (standby
        # forensics); tick errors stay in-memory — they can repeat every
        # period and the tail is append-only
        if (self.store is not None and kind != "tick_error"
                and self.role == "active"):
            try:
                self.store.append_fleet_event(
                    dict(fields, event=kind, manager_id=self.manager_id,
                         time=time.time()))
            except OSError:
                pass

    def status(self) -> Dict[str, Any]:
        with self._lock:
            shards = {s.shard_id: s.to_json()
                      for s in self._shards.values()}
            version = self._version
            stats = dict(self.stats)
            experiments = len(self._experiments)
        return {"version": version, "shards": shards,
                "workers": self.registry.to_json(),
                "experiments": experiments, "stats": stats,
                "period": self.registry.period,
                "manager_id": self.manager_id, "role": self.role,
                "term": self.term}
