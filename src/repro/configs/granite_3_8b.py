"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0 family]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49_155,
    act="swiglu", norm="rmsnorm", use_bias=False, tie_embeddings=False,
)
