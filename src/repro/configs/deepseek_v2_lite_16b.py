"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed experts top-6 +
2 shared, first layer dense.  The assignment line lists both "64e" and "160
routed"; 64 matches V2-*Lite* (160 is full V2) — see DESIGN.md.
[arXiv:2405.04434]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    moe=True, n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    first_dense_layers=1, dense_d_ff=10944,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    act="swiglu", norm="rmsnorm", use_bias=False, tie_embeddings=False,
)
