"""command-r-plus-104b [dense] — GQA, no-bias, parallel block, tied
embeddings (Cohere style). [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256_000,
    act="swiglu", norm="layernorm", use_bias=False, tie_embeddings=True,
    parallel_block=True, rope_theta=75_000.0,
)
