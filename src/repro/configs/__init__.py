from repro.configs.registry import (cache_specs, concrete_inputs, get_config,
                                    input_specs, list_archs)
