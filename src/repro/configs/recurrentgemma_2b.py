"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern (R,R,A);
26 layers = 8x(R,R,A) + 2xR tail.  Deviation (DESIGN.md): RG-LRU gates are
dense rather than block-diagonal. [arXiv:2402.19427]"""
from repro.models.common import LOCAL_ATTN, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000, d_rnn=2560, conv_width=4, window=2048,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    act="geglu", norm="rmsnorm", use_bias=False, tie_embeddings=True,
    scale_embed=True, logit_softcap=30.0,
)
