"""granite-moe-3b-a800m [moe] — 40 experts top-8 (assignment also says "32
experts", which belongs to 1b-a400m; 40 matches 3b-a800m — see DESIGN.md).
[hf:ibm-granite/granite-3.0 moe family]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    moe=True, n_experts=40, n_shared_experts=0, top_k=8, d_ff_expert=512,
    act="swiglu", norm="rmsnorm", use_bias=False, tie_embeddings=True,
)
