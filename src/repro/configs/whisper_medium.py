"""whisper-medium [audio] — enc-dec; conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1536, d).  Deviations (DESIGN.md):
frames padded 1500->1536 for clean sharding; sinusoidal decoder positions
(the 32k decode cell exceeds whisper's learned 448-position table).
[arXiv:2212.04356]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, encoder_layers=24, encoder_seq=1536,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51_865,
    act="gelu", norm="layernorm", use_bias=True, tie_embeddings=True,
    pos_kind="sincos",
)
