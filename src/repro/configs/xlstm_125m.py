"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (pattern m,m,m,s), d_ff=0 (block-
internal projections).  Deviations (DESIGN.md): sLSTM omits its causal conv;
sLSTM blocks carry a 4/3-pf FFN per the xLSTM paper. [arXiv:2405.04517]"""
from repro.models.common import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304, d_rnn=1536, conv_width=4,
    block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
    act="gelu", norm="layernorm", use_bias=False, tie_embeddings=True,
    pos_kind="none",
)
