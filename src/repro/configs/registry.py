"""Architecture registry + per-cell input specs.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of a (architecture x input-shape) cell — weak-type-correct,
shardable, and allocation-free, which is what the multi-pod dry-run lowers
against.  ``concrete_inputs`` materializes small real batches for smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import SHAPES, ModelConfig, ShapeSpec

_MODULES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "command-r-plus-104b": "command_r_plus_104b",
    "granite-3-8b": "granite_3_8b",
    "granite-8b": "granite_8b",
    "whisper-medium": "whisper_medium",
    "llava-next-34b": "llava_next_34b",
    "xlstm-125m": "xlstm_125m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
}


def list_archs():
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def _token_specs(batch: int, seq: int) -> Dict[str, Any]:
    i32 = jnp.int32
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32)}


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for a cell.  train/prefill return a batch dict; decode
    returns {'tokens': (B,)} — the cache is produced by ``cache_specs``."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.compute_dtype
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
    if cfg.family == "vlm":
        s_text = S - cfg.n_img_tokens
        specs = _token_specs(B, s_text)
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model), dt)
    elif cfg.family == "encdec":
        specs = _token_specs(B, S)
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    else:
        specs = _token_specs(B, S)
    if shape.kind == "prefill":
        specs.pop("labels")
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """ShapeDtypeStruct pytree of the decode cache for a cell."""
    from repro.models.model import LM
    B, S = shape.global_batch, shape.seq_len
    enc = cfg.encoder_seq if cfg.family == "encdec" else 0
    return jax.eval_shape(
        lambda: LM(cfg).init_cache(B, S, enc_len=enc))


def concrete_inputs(cfg: ModelConfig, shape: ShapeSpec,
                    seed: int = 0) -> Dict[str, Any]:
    """Small real batches for smoke tests (reduced configs only)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in input_specs(cfg, shape).items():
        if np.issubdtype(s.dtype, np.integer):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape), s.dtype)
        else:
            out[name] = jnp.asarray(rng.normal(0, 1, s.shape), s.dtype)
    return out


__all__ = ["list_archs", "get_config", "input_specs", "cache_specs",
           "concrete_inputs", "SHAPES"]
