"""llava-next-34b [vlm] — anyres tiling frontend is a STUB: input_specs()
provides precomputed patch embeddings (B, 2304, d) prefixed to the token
stream; backbone is the Yi-34B-style decoder. [hf:llava-hf/llava-v1.6]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64_000, n_img_tokens=2304,
    act="swiglu", norm="rmsnorm", use_bias=False, tie_embeddings=False,
    rope_theta=5_000_000.0,
)
