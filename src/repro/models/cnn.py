"""Small convolutional classifier — the paper's §4 alpha-test model
("a convolutional neural network with 3 convolutional layers and 2 fully
connected layers ... trained on the German traffic sign dataset").

The dataset here is a seeded synthetic stand-in (43 classes of structured
32x32x3 patterns + noise) since the container is offline; the architecture
matches the paper's description and is the workload for examples/hpo_cnn.py
and the parallel-speedup benchmark.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

N_CLASSES = 43
IMG = 32


@dataclass(frozen=True)
class CNNConfig:
    channels: Tuple[int, int, int] = (16, 32, 64)
    fc_width: int = 128
    n_classes: int = N_CLASSES


def init_cnn(key, cfg: CNNConfig = CNNConfig()):
    ks = jax.random.split(key, 5)
    c0 = 3
    params = {}
    for i, c in enumerate(cfg.channels):
        params[f"conv{i}"] = {
            "w": jax.random.normal(ks[i], (3, 3, c0, c), jnp.float32)
            * math.sqrt(2.0 / (9 * c0)),
            "b": jnp.zeros((c,), jnp.float32)}
        c0 = c
    flat = cfg.channels[-1] * (IMG // 8) * (IMG // 8)
    params["fc0"] = {
        "w": jax.random.normal(ks[3], (flat, cfg.fc_width), jnp.float32)
        * math.sqrt(2.0 / flat),
        "b": jnp.zeros((cfg.fc_width,), jnp.float32)}
    params["fc1"] = {
        "w": jax.random.normal(ks[4], (cfg.fc_width, cfg.n_classes),
                               jnp.float32) * math.sqrt(2.0 / cfg.fc_width),
        "b": jnp.zeros((cfg.n_classes,), jnp.float32)}
    return params


def cnn_forward(params, x, cfg: CNNConfig = CNNConfig()):
    """x: (B, 32, 32, 3) -> logits (B, n_classes)."""
    for i in range(len(cfg.channels)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc0"]["w"] + params["fc0"]["b"])
    return x @ params["fc1"]["w"] + params["fc1"]["b"]


def cnn_loss(params, batch, cfg: CNNConfig = CNNConfig()):
    logits = cnn_forward(params, batch["image"], cfg)
    onehot = jax.nn.one_hot(batch["label"], cfg.n_classes)
    loss = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(
        jnp.float32))
    return loss, acc


def synthetic_signs(seed: int, n: int) -> Dict[str, np.ndarray]:
    """Class-conditional structured patterns (learnable stand-in for GTSRB)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, n)
    proto_rng = np.random.default_rng(1234)
    protos = proto_rng.normal(0, 1, (N_CLASSES, IMG, IMG, 3)).astype(
        np.float32)
    # low-frequency class structure: blur prototypes along both axes, then
    # renormalize so the class signal survives the additive noise
    for _ in range(3):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, 1, 2)) / 3
    protos /= protos.std(axis=(1, 2, 3), keepdims=True)
    imgs = protos[labels] + rng.normal(0, 0.5, (n, IMG, IMG, 3)).astype(
        np.float32)
    return {"image": imgs.astype(np.float32), "label": labels.astype(
        np.int32)}


def train_cnn(assignment: Dict, steps: int = 60, batch: int = 64,
              seed: int = 0, report=None) -> float:
    """Train with the given hyperparameters, return validation accuracy —
    the trial function for examples/hpo_cnn.py."""
    cfg = CNNConfig(fc_width=int(assignment.get("fc_width", 128)))
    lr = float(assignment.get("lr", 1e-3))
    momentum = float(assignment.get("momentum", 0.9))
    params = init_cnn(jax.random.key(seed), cfg)
    vel = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, vel, batch_):
        (loss, acc), g = jax.value_and_grad(
            functools.partial(cnn_loss, cfg=cfg), has_aux=True)(
                params, batch_)
        vel = jax.tree.map(lambda v, gg: momentum * v - lr * gg, vel, g)
        params = jax.tree.map(jnp.add, params, vel)
        return params, vel, loss, acc

    val = synthetic_signs(9999, 256)
    val = jax.tree.map(jnp.asarray, val)
    for t in range(steps):
        data = jax.tree.map(jnp.asarray, synthetic_signs(seed * 10_000 + t,
                                                         batch))
        params, vel, loss, acc = step(params, vel, data)
        if report is not None and t % 10 == 9:
            _, va = cnn_loss(params, val, cfg)
            report(t, float(va))
    _, vacc = cnn_loss(params, val, cfg)
    return float(vacc)
