"""Shared primitive layers: norms, dense, RoPE, MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays (pytree-native: vmap-able for
population training, trivially shardable for pjit).  Every init function is
usable under ``jax.eval_shape`` so the dry-run never allocates.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, cfg, *, scale: Optional[float] = None,
               bias: Optional[bool] = None) -> Params:
    scale = 1.0 / math.sqrt(d_in) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), cfg.store_dtype) * scale)}
    if cfg.use_bias if bias is None else bias:
        p["b"] = jnp.zeros((d_out,), cfg.store_dtype)
    return p


def dense(p: Params, x: jnp.ndarray, dtype=None) -> jnp.ndarray:
    dtype = dtype or x.dtype
    y = jnp.einsum("...i,io->...o", x, p["w"].astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return constrain(y)  # anchor to batch/seq sharding (no-op off-mesh)


def init_norm(d: int, cfg, kind: Optional[str] = None) -> Params:
    kind = kind or cfg.norm
    p = {"scale": jnp.ones((d,), cfg.store_dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.store_dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm or LayerNorm (decided by presence of a bias), f32 statistics."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:            # RMSNorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                       # (dim/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dim/2)
    sin = jnp.sin(angles)[..., :, None, :]               # (..., S, 1, dim/2)
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, cfg) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "gate": init_dense(k1, d_model, d_ff, cfg),
            "up": init_dense(k2, d_model, d_ff, cfg),
            "down": init_dense(k3, d_ff, d_model, cfg),
        }
    return {
        "up": init_dense(k1, d_model, d_ff, cfg),
        "down": init_dense(k2, d_ff, d_model, cfg),
    }


def mlp(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if "gate" in p:
        act = jax.nn.gelu if cfg.act == "geglu" else jax.nn.silu
        return dense(p["down"], act(dense(p["gate"], x)) * dense(p["up"], x))
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, cfg) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model), cfg.store_dtype) * 0.02}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed(p: Params, x: jnp.ndarray, *, softcap: float = 0.0) -> jnp.ndarray:
    logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap else x


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    if mask is not None:
        valid = jnp.logical_and(valid, mask > 0)
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
