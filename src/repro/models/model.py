"""Unified LM covering all ten assigned architectures.

One class, five families:
  dense / moe     decoder-only transformer (GQA or MLA attention, MLP or MoE FFN)
  hybrid          Griffin-style (RG-LRU, RG-LRU, local-attn) stacks
  ssm             xLSTM (mLSTM / sLSTM) stacks
  vlm             decoder LM consuming a precomputed patch-embedding prefix (stub)
  encdec          whisper: stub-frame encoder + cross-attending decoder

Layer stacks are organised into homogeneous *groups* and applied with
``lax.scan`` so compiled HLO size is O(#groups), not O(#layers); parameter
leaves carry a leading ``repeats`` dim per group.  The same structure is what
makes population-vmap training (core/vmap_trials.py) cheap: one more leading
dim, zero code changes here.

API (all pure functions of pytrees — vmap/pjit compose freely):
  init(rng) -> params
  loss(params, batch) -> (scalar, metrics)         # train_step target
  forward(params, batch) -> (logits, aux)
  prefill(params, batch, cache_len) -> (cache, last_logits)
  decode_step(params, cache, tokens) -> (logits, cache)
  init_cache(batch_size, cache_len) -> cache
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.common import (ATTN, LOCAL_ATTN, MLSTM, RGLRU, SLSTM,
                                 ModelConfig)

Params = Dict[str, Any]

XATTN = "xattn"  # whisper decoder layer (self + cross + mlp)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str          # attn | local | rglru | mlstm | slstm | xattn
    ffn: str           # mlp | dense_mlp | moe | none


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    pattern: Tuple[LayerSpec, ...]
    repeats: int


def build_groups(cfg: ModelConfig) -> Tuple[GroupSpec, ...]:
    if cfg.family == "encdec":
        return (GroupSpec((LayerSpec(XATTN, "mlp"),), cfg.n_layers),)
    if cfg.moe:
        out = []
        if cfg.first_dense_layers:
            out.append(GroupSpec((LayerSpec(ATTN, "dense_mlp"),),
                                 cfg.first_dense_layers))
        out.append(GroupSpec((LayerSpec(ATTN, "moe"),),
                             cfg.n_layers - cfg.first_dense_layers))
        return tuple(out)
    groups = []
    for pattern, reps in cfg.layer_groups():
        specs = tuple(
            LayerSpec(k, "none" if cfg.d_ff == 0 else "mlp") for k in pattern)
        groups.append(GroupSpec(specs, reps))
    return tuple(groups)


# ==========================================================================
# per-layer init
# ==========================================================================
def _init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": L.init_norm(cfg.d_model, cfg)}
    if spec.kind in (ATTN, LOCAL_ATTN):
        p["attn"] = A.init_attention(ks[0], cfg)
    elif spec.kind == XATTN:
        p["attn"] = A.init_attention(ks[0], cfg)
        p["ln_x"] = L.init_norm(cfg.d_model, cfg)
        p["cross"] = A.init_attention(ks[3], cfg, cross=True)
    elif spec.kind == RGLRU:
        p["rglru"] = R.init_rglru_block(ks[0], cfg)
    elif spec.kind == MLSTM:
        p["mlstm"] = R.init_mlstm_block(ks[0], cfg)
    elif spec.kind == SLSTM:
        p["slstm"] = R.init_slstm_block(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.ffn != "none" and not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg.d_model, cfg)
    if spec.ffn == "mlp":
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg)
    elif spec.ffn == "dense_mlp":
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model,
                              cfg.dense_d_ff or cfg.d_ff, cfg)
    elif spec.ffn == "moe":
        p["ffn"] = M.init_moe(ks[2], cfg)
    return p


def _ffn_apply(spec: LayerSpec, p: Params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if spec.ffn == "moe":
        return M.moe_forward(p["ffn"], x, cfg)
    return L.mlp(p["ffn"], x, cfg), jnp.zeros((), jnp.float32)


# ==========================================================================
# per-layer forward / prefill / decode
# ==========================================================================
def _layer_fwd(spec: LayerSpec, p: Params, x, positions, cfg,
               enc=None, enc_positions=None, collect_cache=False,
               cache_len: int = 0):
    """Returns (x, aux, cache_entry_or_{})."""
    aux = jnp.zeros((), jnp.float32)
    entry: Params = {}
    eps = cfg.norm_eps
    k = spec.kind
    h = L.apply_norm(p["ln1"], x, eps)
    window = cfg.window if k == LOCAL_ATTN else 0

    if k in (ATTN, LOCAL_ATTN, XATTN):
        if collect_cache:
            att, kv = A.attn_forward(p["attn"], h, positions, cfg,
                                     window=window, return_kv=True)
            entry.update(_pad_kv(kv, cache_len, window, cfg))
        else:
            att = A.attn_forward(p["attn"], h, positions, cfg, window=window)
        if cfg.parallel_block:                 # cohere: one norm, parallel
            ff, aux = _ffn_apply(spec, p, h, cfg)
            return x + att + ff, aux, entry
        x = x + att
        if k == XATTN:
            hx = L.apply_norm(p["ln_x"], x, eps)
            if collect_cache:
                xa, ckv = A.attn_forward(
                    p["cross"], hx, positions, cfg, kv_source=enc,
                    kv_positions=enc_positions, return_kv=True)
                entry["ck"], entry["cv"] = ckv["k"], ckv["v"]
            else:
                xa = A.attn_forward(p["cross"], hx, positions, cfg,
                                    kv_source=enc, kv_positions=enc_positions)
            x = x + xa
    elif k == RGLRU:
        if collect_cache:
            y, c = R.rglru_forward(p["rglru"], h, cfg, return_cache=True)
            entry.update(c)
        else:
            y = R.rglru_forward(p["rglru"], h, cfg)
        x = x + y
    elif k == MLSTM:
        if collect_cache:
            y, c = R.mlstm_forward(p["mlstm"], h, cfg, return_cache=True)
            entry.update(c)
        else:
            y = R.mlstm_forward(p["mlstm"], h, cfg)
        return x + y, aux, entry
    elif k == SLSTM:
        if collect_cache:
            y, c = R.slstm_forward(p["slstm"], h, cfg, return_cache=True)
            entry.update(c)
        else:
            y = R.slstm_forward(p["slstm"], h, cfg)
        return x + y, aux, entry

    if spec.ffn != "none":
        ff, aux = _ffn_apply(spec, p, L.apply_norm(p["ln2"], x, eps), cfg)
        x = x + ff
    return x, aux, entry


def _pad_kv(kv: Params, cache_len: int, window: int, cfg) -> Params:
    """Fit prefill K/V into the fixed cache buffer (ring-layout for local)."""
    out = {}
    S = next(iter(kv.values())).shape[1]
    buf_len = min(cache_len, window) if window else cache_len
    for name, v in kv.items():
        if window:
            # keep the last `buf_len` entries, placed at slot pos % buf_len
            tail = v[:, -buf_len:] if S >= buf_len else v
            keep = tail.shape[1]
            start = (S - keep) % buf_len
            rolled = jnp.roll(
                jnp.pad(tail, ((0, 0), (0, buf_len - keep)) +
                        ((0, 0),) * (v.ndim - 2)), start, axis=1)
            out[name] = rolled.astype(cfg.compute_dtype)
        else:
            pad = cache_len - S
            out[name] = jnp.pad(v, ((0, 0), (0, pad)) +
                                ((0, 0),) * (v.ndim - 2)
                                ).astype(cfg.compute_dtype)
    return out


def _layer_decode(spec: LayerSpec, p: Params, x, cache: Params, pos, cfg):
    """x: (B,1,d); returns (x, new_cache_entry)."""
    eps = cfg.norm_eps
    k = spec.kind
    h = L.apply_norm(p["ln1"], x, eps)
    window = cfg.window if k == LOCAL_ATTN else 0
    if k in (ATTN, LOCAL_ATTN, XATTN):
        self_cache = {n: cache[n] for n in cache if n not in ("ck", "cv")}
        att, new_self = A.attn_decode(p["attn"], h, self_cache, pos, cfg,
                                      window=window)
        if cfg.parallel_block:
            ff, _ = _ffn_apply(spec, p, h, cfg)
            new = dict(new_self)
            return x + att + ff, new
        x = x + att
        new = dict(new_self)
        if k == XATTN:
            hx = L.apply_norm(p["ln_x"], x, eps)
            B = x.shape[0]
            S_enc = cache["ck"].shape[1]
            q = A.dense3(p["cross"]["wq"], hx, cfg.n_heads, cfg.hd)[:, 0]
            stats = A.decode_attend_chunk(
                q, cache["ck"], cache["cv"], jnp.full((B,), 1 << 30),
                jnp.broadcast_to(jnp.arange(S_enc)[None], (B, S_enc)),
                scale=1.0 / math.sqrt(cfg.hd))
            out = A.combine_decode([stats]).astype(x.dtype)
            xa = L.dense(p["cross"]["wo"], out.reshape(B, -1))[:, None]
            x = x + xa
            new["ck"], new["cv"] = cache["ck"], cache["cv"]
    elif k == RGLRU:
        y, new = R.rglru_decode(p["rglru"], h, cache, cfg)
        x = x + y
    elif k == MLSTM:
        y, new = R.mlstm_decode(p["mlstm"], h, cache, cfg)
        return x + y, new
    elif k == SLSTM:
        y, new = R.slstm_decode(p["slstm"], h, cache, cfg)
        return x + y, new
    else:
        raise ValueError(k)
    if spec.ffn != "none" and not cfg.parallel_block:
        ff, _ = _ffn_apply(spec, p, L.apply_norm(p["ln2"], x, eps), cfg)
        x = x + ff
    return x, new


def _init_cache_entry(spec: LayerSpec, cfg: ModelConfig, batch: int,
                      cache_len: int, enc_len: int = 0) -> Params:
    k = spec.kind
    if k in (ATTN, XATTN):
        e = A.init_cache_attn(cfg, batch, cache_len)
        if k == XATTN:
            e["ck"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.hd),
                                cfg.compute_dtype)
            e["cv"] = jnp.zeros_like(e["ck"])
        return e
    if k == LOCAL_ATTN:
        return A.init_cache_attn(cfg, batch, cache_len, window=cfg.window)
    if k == RGLRU:
        return R.init_rglru_cache(cfg, batch)
    if k == MLSTM:
        return R.init_mlstm_cache(cfg, batch)
    if k == SLSTM:
        return R.init_slstm_cache(cfg, batch)
    raise ValueError(k)


# ==========================================================================
# sinusoidal positions (whisper)
# ==========================================================================
def _sincos(positions: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ==========================================================================
# the model
# ==========================================================================
class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = build_groups(cfg)

    # ------------------------------------------------------------- params
    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_enc, k_out, k_g = jax.random.split(rng, 4)
        params: Params = {
            "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, cfg),
            "final_norm": L.init_norm(cfg.d_model, cfg),
            "groups": [],
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.init_embedding(
                k_out, cfg.vocab_size, cfg.d_model, cfg)
        gkeys = jax.random.split(k_g, len(self.groups))
        for g, gk in zip(self.groups, gkeys):
            pkeys = jax.random.split(gk, len(g.pattern))
            gp = {}
            for j, (spec, pk) in enumerate(zip(g.pattern, pkeys)):
                rkeys = jax.random.split(pk, g.repeats)
                gp[str(j)] = jax.vmap(
                    lambda k_, s=spec: _init_layer(k_, s, cfg))(rkeys)
            params["groups"].append(gp)
        if cfg.family == "encdec":
            params["encoder"] = self._init_encoder(k_enc)
        return params

    def _init_encoder(self, key) -> Params:
        cfg = self.cfg
        n = cfg.encoder_layers
        k_l, k_n = jax.random.split(key)
        spec = LayerSpec(ATTN, "mlp")
        rkeys = jax.random.split(k_l, n)
        return {
            "layers": jax.vmap(lambda k_: _init_layer(k_, spec, cfg))(rkeys),
            "norm": L.init_norm(cfg.d_model, cfg),
        }

    def param_shapes(self, deduped: bool = False) -> Params:
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------------ helpers
    def _maybe_remat(self, fn):
        cfg = self.cfg
        if cfg.remat == "none":
            return fn
        if cfg.remat == "dots":      # save matmul outputs, recompute the rest
            pol = getattr(jax.checkpoint_policies, "dots_saveable", None)
            return jax.checkpoint(fn, policy=pol)
        return jax.checkpoint(fn)    # full: save only layer boundaries

    def _run_stack(self, params, x, positions, *, enc=None, enc_positions=None,
                   mode="train", cache=None, pos=None, cache_len=0):
        """Apply every group; returns (x, aux, new_cache_groups)."""
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)
        new_groups: List[Params] = []
        for gi, g in enumerate(self.groups):
            gp = params["groups"][gi]
            gc = cache[gi] if cache is not None else None

            if mode == "decode":
                def step(carry, xs, _g=g):
                    xx = carry
                    lp, lc = xs
                    nc = {}
                    for j, spec in enumerate(_g.pattern):
                        xx, nce = _layer_decode(spec, lp[str(j)], xx,
                                                lc[str(j)], pos, cfg)
                        nc[str(j)] = nce
                    return constrain(xx), nc
                if cfg.scan_layers:
                    x, nc = jax.lax.scan(step, x, (gp, gc))
                else:
                    ncl = []
                    for r in range(g.repeats):
                        x, e = step(x, jax.tree.map(lambda a: a[r], (gp, gc)))
                        ncl.append(e)
                    nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncl)
                new_groups.append(nc)
                continue

            collect = mode == "prefill"

            def step(carry, lp, _g=g, _collect=collect):
                xx, aux = carry
                nc = {}
                for j, spec in enumerate(_g.pattern):
                    xx, a, e = _layer_fwd(
                        spec, lp[str(j)], xx, positions, cfg, enc=enc,
                        enc_positions=enc_positions, collect_cache=_collect,
                        cache_len=cache_len)
                    aux = aux + a
                    if _collect:
                        nc[str(j)] = e
                return (constrain(xx), aux), nc

            if cfg.scan_layers:
                fn = self._maybe_remat(step) if mode == "train" else step
                (x, aux0), nc = jax.lax.scan(fn, (x, aux0), gp)
            else:
                ncl = []
                for r in range(g.repeats):
                    (x, aux0), e = step((x, aux0),
                                        jax.tree.map(lambda a: a[r], gp))
                    ncl.append(e)
                nc = (jax.tree.map(lambda *xs: jnp.stack(xs), *ncl)
                      if collect else {})
            new_groups.append(nc)
        return x, aux0, new_groups

    def _embed_in(self, params, tokens):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg.compute_dtype)
        if cfg.scale_embed:
            x = x * math.sqrt(cfg.d_model)
        return constrain(x)

    def _unembed(self, params, x):
        cfg = self.cfg
        table = params["embed" if cfg.tie_embeddings else "unembed"]
        x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
        return constrain(L.unembed(table, x, softcap=cfg.logit_softcap))

    def encode(self, params, frames):
        """Whisper encoder over precomputed (stub) frame embeddings."""
        cfg = self.cfg
        enc = params["encoder"]
        S = frames.shape[1]
        pos = jnp.arange(S)
        x = frames.astype(cfg.compute_dtype) + _sincos(pos, cfg.d_model,
                                                       cfg.compute_dtype)
        spec = LayerSpec(ATTN, "mlp")

        def step(xx, lp):
            h = L.apply_norm(lp["ln1"], xx, cfg.norm_eps)
            att = A.attn_forward(lp["attn"], h, pos, cfg, causal=False)
            xx = xx + att
            ff, _ = _ffn_apply(spec, lp, L.apply_norm(lp["ln2"], xx,
                                                      cfg.norm_eps), cfg)
            return xx + ff, {}

        if cfg.scan_layers:
            x, _ = jax.lax.scan(self._maybe_remat(step), x, enc["layers"])
        else:
            for r in range(cfg.encoder_layers):
                x, _ = step(x, jax.tree.map(lambda a: a[r], enc["layers"]))
        return L.apply_norm(enc["norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------ forward
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (logits over *text* positions, moe aux loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_in(params, tokens)
        enc = enc_positions = None
        n_prefix = 0
        if cfg.family == "vlm":
            img = batch["img_embeds"].astype(cfg.compute_dtype)
            n_prefix = img.shape[1]
            x = jnp.concatenate([img, x], axis=1)
        elif cfg.family == "encdec":
            enc = self.encode(params, batch["frames"])
            enc_positions = jnp.arange(enc.shape[1])
        S = x.shape[1]
        positions = jnp.arange(S)
        if cfg.pos_kind == "sincos":
            x = x + _sincos(positions, cfg.d_model, x.dtype)
        x, aux, _ = self._run_stack(params, x, positions, enc=enc,
                                    enc_positions=enc_positions, mode="train")
        if n_prefix:
            x = x[:, n_prefix:]
        return self._unembed(params, x), aux

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["labels"])
        total = ce + self.cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux": aux,
                       "tokens": jnp.sum(batch["labels"] >= 0)}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, cache_len: int, enc_len: int = 0):
        caches = []
        for g in self.groups:
            gc = {}
            for j, spec in enumerate(g.pattern):
                one = _init_cache_entry(spec, self.cfg, batch, cache_len,
                                        enc_len)
                gc[str(j)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (g.repeats,) + a.shape), one)
            caches.append(gc)
        return {"layers": caches,
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, batch, cache_len: int):
        """Run the full prompt, build a decode cache sized `cache_len`."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_in(params, tokens)
        enc = enc_positions = None
        n_prefix = 0
        if cfg.family == "vlm":
            img = batch["img_embeds"].astype(cfg.compute_dtype)
            n_prefix = img.shape[1]
            x = jnp.concatenate([img, x], axis=1)
        elif cfg.family == "encdec":
            enc = self.encode(params, batch["frames"])
            enc_positions = jnp.arange(enc.shape[1])
        S = x.shape[1]
        positions = jnp.arange(S)
        if cfg.pos_kind == "sincos":
            x = x + _sincos(positions, cfg.d_model, x.dtype)
        x, _, layer_caches = self._run_stack(
            params, x, positions, enc=enc, enc_positions=enc_positions,
            mode="prefill", cache_len=cache_len)
        logits = self._unembed(params, x[:, -1:])[:, 0]
        cache = {"layers": layer_caches,
                 "pos": jnp.full((tokens.shape[0],), S, jnp.int32)}
        return cache, logits

    def decode_step(self, params, cache, tokens):
        """tokens: (B,) int32 -> (logits (B,V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed_in(params, tokens[:, None])
        if cfg.pos_kind == "sincos":
            x = x + _sincos(pos[:, None], cfg.d_model, x.dtype)
        x, _, new_layers = self._run_stack(
            params, x, None, mode="decode", cache=cache["layers"], pos=pos)
        logits = self._unembed(params, x[:, 0])
        return logits, {"layers": new_layers, "pos": pos + 1}
