"""Recurrent temporal-mixing blocks: RG-LRU (Griffin/RecurrentGemma) and
xLSTM's mLSTM / sLSTM cells.

* RG-LRU trains with a parallel ``associative_scan`` (O(S log S) depth) and
  decodes with an O(1) state update — the reason recurrentgemma runs the
  long_500k cell.
* mLSTM uses a **stabilized chunkwise-recurrent** formulation (parallel
  D-matrix inside a chunk, exact recurrent state carry across chunks) — the
  same scheme production linear-attention kernels use; both train and prefill
  share it, decode is the O(1) recurrent step.
* sLSTM has a true hidden-to-hidden recurrence (block-diagonal per head) and
  therefore trains with ``lax.scan`` over time, exactly as the paper defines.

Deviations from the sources (recorded in DESIGN.md): RG-LRU gates are dense
rather than block-diagonal; sLSTM omits its causal conv.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain_at
from repro.models import layers as L
from repro.models.common import ModelConfig

Params = Dict[str, jnp.ndarray]
_RGLRU_C = 8.0
_MLSTM_CHUNK = 256


# ==========================================================================
# temporal causal conv (depthwise)
# ==========================================================================
def init_conv(key, width: int, channels: int, cfg) -> Params:
    return {"w": jax.random.normal(key, (width, channels), cfg.store_dtype) * 0.1,
            "b": jnp.zeros((channels,), cfg.store_dtype)}


def causal_conv(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,C); width-W depthwise causal conv as W shifted adds."""
    W = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    y = x * w[W - 1]
    for j in range(W - 1):
        shift = W - 1 - j
        y = y + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[j]
    return y + p["b"].astype(x.dtype)


def conv_decode(p: Params, x1: jnp.ndarray, buf: jnp.ndarray):
    """x1: (B,C) new input; buf: (B,W-1,C) previous inputs (oldest first)."""
    W = p["w"].shape[0]
    w = p["w"].astype(x1.dtype)
    hist = jnp.concatenate([buf, x1[:, None]], axis=1)          # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", hist, w) + p["b"].astype(x1.dtype)
    return y, hist[:, 1:]


# ==========================================================================
# RG-LRU (Griffin recurrent block: two branches, conv, gated LRU)
# ==========================================================================
def init_rglru_block(key, cfg: ModelConfig) -> Params:
    d, r = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 7)
    # Λ init so a = exp(-c softplus(Λ)) is in (0.9, 0.999)
    u = jax.random.uniform(ks[5], (r,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / _RGLRU_C) - 1.0)        # inv softplus
    return {
        "in_x": L.init_dense(ks[0], d, r, cfg),
        "in_gate": L.init_dense(ks[1], d, r, cfg),
        "conv": init_conv(ks[2], cfg.conv_width, r, cfg),
        "w_a": L.init_dense(ks[3], r, r, cfg),
        "w_i": L.init_dense(ks[4], r, r, cfg),
        "lam": lam.astype(cfg.store_dtype),
        "out": L.init_dense(ks[6], r, d, cfg),
    }


def _rglru_coeffs(p, xr):
    """xr: (...,r) conv output -> log_a, b (both f32)."""
    x32 = xr.astype(jnp.float32)
    a_gate = jax.nn.sigmoid(L.dense(p["w_a"], x32, dtype=jnp.float32))
    i_gate = jax.nn.sigmoid(L.dense(p["w_i"], x32, dtype=jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * a_gate
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i_gate * x32)
    return log_a, b


def rglru_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_cache: bool = False):
    """Training/prefill pass. x: (B,S,d)."""
    gate = jax.nn.gelu(L.dense(p["in_gate"], x))
    xr = causal_conv(p["conv"], L.dense(p["in_x"], x))
    log_a, b = _rglru_coeffs(p, xr)

    def op(c1, c2):
        (la1, h1), (la2, h2) = c1, c2
        return la1 + la2, h1 * jnp.exp(la2) + h2

    _, h = jax.lax.associative_scan(op, (log_a, b), axis=1)
    y = L.dense(p["out"], (h.astype(x.dtype) * gate))
    if return_cache:
        W = cfg.conv_width
        pre = L.dense(p["in_x"], x[:, -(W - 1):])
        pad = W - 1 - pre.shape[1]
        if pad:
            pre = jnp.pad(pre, ((0, 0), (pad, 0), (0, 0)))
        return y, {"h": h[:, -1].astype(jnp.float32), "conv": pre}
    return y


def rglru_decode(p: Params, x: jnp.ndarray, cache: Dict, cfg: ModelConfig):
    """x: (B,1,d) -> (y, new_cache); O(1) per step."""
    x1 = x[:, 0]
    gate = jax.nn.gelu(L.dense(p["in_gate"], x1))
    xr_raw = L.dense(p["in_x"], x1)
    xr, conv_buf = conv_decode(p["conv"], xr_raw, cache["conv"])
    log_a, b = _rglru_coeffs(p, xr)
    h = cache["h"] * jnp.exp(log_a) + b
    y = L.dense(p["out"], h.astype(x.dtype) * gate)
    return y[:, None], {"h": h, "conv": conv_buf}


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict:
    return {"h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn),
                              cfg.compute_dtype)}


# ==========================================================================
# mLSTM (xLSTM matrix memory) — stabilized chunkwise recurrent
# ==========================================================================
def init_mlstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.d_rnn or 2 * d                 # inner width (pf=2)
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "up_m": L.init_dense(ks[0], d, di, cfg),
        "up_g": L.init_dense(ks[1], d, di, cfg),
        "conv": init_conv(ks[2], cfg.conv_width, di, cfg),
        "wq": L.init_dense(ks[3], di, di, cfg),
        "wk": L.init_dense(ks[4], di, di, cfg),
        "wv": L.init_dense(ks[5], di, di, cfg),
        "w_if": L.init_dense(ks[6], di, 2 * H, cfg, bias=True),
        "skip": jnp.ones((di,), cfg.store_dtype),
        "down": L.init_dense(ks[7], di, d, cfg),
    }


def _mlstm_qkvif(p, x, cfg):
    di = p["up_m"]["w"].shape[1]
    H = cfg.n_heads
    xm = L.dense(p["up_m"], x)
    gate = jax.nn.silu(L.dense(p["up_g"], x))
    xc = jax.nn.silu(causal_conv(p["conv"], xm))
    B, S = x.shape[:2]
    q = L.dense(p["wq"], xc).reshape(B, S, H, -1)
    k = L.dense(p["wk"], xc).reshape(B, S, H, -1)
    v = L.dense(p["wv"], xm).reshape(B, S, H, -1)
    i_f = L.dense(p["w_if"], xc, dtype=jnp.float32)
    i_t, f_t = jnp.split(i_f, 2, axis=-1)                       # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_t + 1.0)
    return q, k, v, i_t, log_f, gate, xc


def _mlstm_chunk(carry, inp, scale):
    """One chunk of stabilized chunkwise mLSTM.  All f32.
    carry: (C (B,H,D,D), n (B,H,D), m (B,H)); inp: q,k,v,(B,L,H,D) i,lf (B,L,H)."""
    C_in, n_in, m_in = carry
    q, k, v, i_t, lf = inp
    B, Lc, H, D = q.shape
    cums = jnp.cumsum(lf, axis=1)                               # (B,L,H)
    total = cums[:, -1]                                         # (B,H)
    # intra-chunk log weights D~[t,s] = cums_t - cums_s + i_s (s<=t)
    dt = (cums[:, :, None] - cums[:, None, :, :]
          + i_t[:, None, :, :])                                 # (B,t,s,H)
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    dt = jnp.where(tri[None, :, :, None], dt, -jnp.inf)
    m_intra = jnp.max(dt, axis=2)                               # (B,t,H)
    m_t = jnp.maximum(m_intra, m_in[:, None] + cums)            # (B,t,H)
    m_t = jnp.maximum(m_t, -60.0)                               # floor
    w_intra = jnp.exp(dt - m_t[:, :, None])                     # (B,t,s,H)
    w_inter = jnp.exp(cums + m_in[:, None] - m_t)               # (B,t,H)

    qs = q * scale
    s_qk = jnp.einsum("bthd,bshd->btsh", qs, k)                 # (B,t,s,H)
    num = (jnp.einsum("btsh,bshd->bthd", s_qk * w_intra, v)
           + jnp.einsum("bthd,bhde->bthe", qs, C_in) * w_inter[..., None])
    den = (jnp.einsum("btsh,btsh->bth", s_qk, w_intra)
           + jnp.einsum("bthd,bhd->bth", qs, n_in) * w_inter)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # state carry to the next chunk
    m_out = jnp.maximum(m_in + total,
                        jnp.max(total[:, None] - cums + i_t, axis=1))
    m_out = jnp.maximum(m_out, -60.0)
    w_st = jnp.exp(total[:, None] - cums + i_t - m_out[:, None])  # (B,s,H)
    C_out = (C_in * jnp.exp(m_in + total - m_out)[..., None, None]
             + jnp.einsum("bshd,bshe,bsh->bhde", k, v, w_st))
    n_out = (n_in * jnp.exp(m_in + total - m_out)[..., None]
             + jnp.einsum("bshd,bsh->bhd", k, w_st))
    return (C_out, n_out, m_out), h


def mlstm_cell(q, k, v, i_t, log_f, state, chunk: int = _MLSTM_CHUNK):
    """Full-sequence stabilized mLSTM. Returns (h (B,S,H,D), final state)."""
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    f32 = lambda a: a.astype(jnp.float32)
    q, k, v = f32(q), f32(k), f32(v)
    if state is None:
        state = (jnp.zeros((B, H, D, D), jnp.float32),
                 jnp.zeros((B, H, D), jnp.float32),
                 jnp.full((B, H), -60.0, jnp.float32))
    state = tuple(constrain_at(s, 0) for s in state)
    Lc = min(chunk, S)
    n_chunks = math.ceil(S / Lc)
    pad = n_chunks * Lc - S
    def pad_t(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=fill) if pad else a
    # padded steps get log_f=0, i=-inf(-1e9): they don't alter the state
    qp, kp, vp = pad_t(q), pad_t(k), pad_t(v)
    ip, lfp = pad_t(i_t, -1e9), pad_t(log_f, 0.0)
    resh = lambda a: constrain_at(
        a.reshape(B, n_chunks, Lc, *a.shape[2:]).swapaxes(0, 1), 1)
    xs = tuple(resh(a) for a in (qp, kp, vp, ip, lfp))
    state, hs = jax.lax.scan(lambda c, i: _mlstm_chunk(c, i, scale), state, xs)
    h = hs.swapaxes(0, 1).reshape(B, n_chunks * Lc, H, D)[:, :S]
    return h, state


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_cache: bool = False):
    q, k, v, i_t, log_f, gate, xc = _mlstm_qkvif(p, x, cfg)
    h, state = mlstm_cell(q, k, v, i_t, log_f, None)
    h = h.reshape(*x.shape[:2], -1).astype(x.dtype)
    h = h + xc * p["skip"].astype(x.dtype)
    y = L.dense(p["down"], h * gate)
    if return_cache:
        W = cfg.conv_width
        xm = L.dense(p["up_m"], x[:, -(W - 1):])
        pad = W - 1 - xm.shape[1]
        if pad:
            xm = jnp.pad(xm, ((0, 0), (pad, 0), (0, 0)))
        return y, {"C": state[0], "n": state[1], "m": state[2], "conv": xm}
    return y


def mlstm_decode(p: Params, x: jnp.ndarray, cache: Dict, cfg: ModelConfig):
    x1 = x[:, 0]
    H = cfg.n_heads
    xm = L.dense(p["up_m"], x1)
    gate = jax.nn.silu(L.dense(p["up_g"], x1))
    xc_raw, conv_buf = conv_decode(p["conv"], xm, cache["conv"])
    xc = jax.nn.silu(xc_raw)
    B = x1.shape[0]
    q = L.dense(p["wq"], xc).reshape(B, H, -1).astype(jnp.float32)
    k = L.dense(p["wk"], xc).reshape(B, H, -1).astype(jnp.float32)
    v = L.dense(p["wv"], xm).reshape(B, H, -1).astype(jnp.float32)
    i_f = L.dense(p["w_if"], xc, dtype=jnp.float32)
    i_t, f_t = jnp.split(i_f, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_t + 1.0)
    D = q.shape[-1]
    C_in, n_in, m_in = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m_in, i_t)
    fp = jnp.exp(log_f + m_in - m_new)[..., None]
    ip = jnp.exp(i_t - m_new)[..., None]
    C = C_in * fp[..., None] + ip[..., None] * k[..., :, None] * v[..., None, :]
    n = n_in * fp + ip * k
    qs = q / math.sqrt(D)
    num = jnp.einsum("bhd,bhde->bhe", qs, C)
    den = jnp.einsum("bhd,bhd->bh", qs, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, -1).astype(x.dtype) + xc * p["skip"].astype(x.dtype)
    y = L.dense(p["down"], h * gate)
    return y[:, None], {"C": C, "n": n, "m": m_new, "conv": conv_buf}


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    di = cfg.d_rnn or 2 * cfg.d_model
    H = cfg.n_heads
    D = di // H
    return {"C": jnp.zeros((batch, H, D, D), jnp.float32),
            "n": jnp.zeros((batch, H, D), jnp.float32),
            "m": jnp.full((batch, H), -60.0, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di),
                              cfg.compute_dtype)}


# ==========================================================================
# sLSTM (xLSTM scalar memory; true recurrence -> lax.scan over time)
# ==========================================================================
def init_slstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    ffd = max(1, int(math.ceil(4 * d / 3 / 64)) * 64)   # pf 4/3, rounded
    return {
        "w_in": L.init_dense(ks[0], d, 4 * d, cfg, bias=True),
        # block-diagonal recurrence, per head: (4, H, dh, dh)
        "r": jax.random.normal(ks[1], (4, H, dh, dh), cfg.store_dtype)
             / math.sqrt(dh),
        "gn": jnp.ones((d,), cfg.store_dtype),
        "ffn": L.init_mlp(ks[2], d, ffd, cfg),
        "ffn_norm": L.init_norm(d, cfg),
    }


def _slstm_step(p, cfg, carry, zx):
    """carry: (c,n,h,m) each (B,H,dh); zx: pre-activations (B,4d)."""
    c, n, h, m = carry
    B = zx.shape[0]
    H = cfg.n_heads
    dh = c.shape[-1]
    r = p["r"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->gbhe", h, r)                    # (4,B,H,dh)
    zi, zf, zz, zo = jnp.split(
        zx.astype(jnp.float32).reshape(B, 4, H, dh).swapaxes(0, 1), 4, axis=0)
    zi, zf, zz, zo = (zi[0] + rec[0], zf[0] + rec[1],
                      zz[0] + rec[2], zo[0] + rec[3])
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, zi)
    i_p = jnp.exp(zi - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def _group_norm(scale, x, eps):
    # per-head group norm over the last dim, x: (B,S,d)->(B,S,H,dh) normed
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_cache: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    zx = L.dense(p["w_in"], x)                                  # (B,S,4d)
    init = tuple(jnp.zeros((B, H, dh), jnp.float32) for _ in range(3)) + (
        jnp.full((B, H, dh), -30.0, jnp.float32),)
    init = tuple(constrain_at(s, 0) for s in init)
    carry, hs = jax.lax.scan(
        lambda c, z: _slstm_step(p, cfg, c, z), init,
        constrain_at(zx.swapaxes(0, 1), 1))
    h = hs.swapaxes(0, 1)                                       # (B,S,H,dh)
    h = _group_norm(p["gn"], h, cfg.norm_eps).reshape(B, S, d)
    y = (h * p["gn"].astype(jnp.float32)).astype(x.dtype)
    y = y + L.mlp(p["ffn"], L.apply_norm(p["ffn_norm"], y, cfg.norm_eps), cfg)
    if return_cache:
        c, n, hh, m = carry
        return y, {"c": c, "n": n, "h": hh, "m": m}
    return y


def slstm_decode(p: Params, x: jnp.ndarray, cache: Dict, cfg: ModelConfig):
    B = x.shape[0]
    d = x.shape[-1]
    H = cfg.n_heads
    zx = L.dense(p["w_in"], x[:, 0])
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    carry, h = _slstm_step(p, cfg, carry, zx)
    h = _group_norm(p["gn"], h[:, None], cfg.norm_eps).reshape(B, 1, d)
    y = (h * p["gn"].astype(jnp.float32)).astype(x.dtype)
    y = y + L.mlp(p["ffn"], L.apply_norm(p["ffn_norm"], y, cfg.norm_eps), cfg)
    c, n, hh, m = carry
    return y, {"c": c, "n": n, "h": hh, "m": m}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, H, dh), -30.0, jnp.float32)}
