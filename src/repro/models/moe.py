"""Mixture-of-Experts FFN: top-k routing with per-sequence capacity.

TPU-native choices
------------------
* Routing/dispatch math is *per sequence* (cumsum along the sequence axis
  only), so a batch sharded over mesh axes needs **zero** cross-device
  communication for dispatch — XLA shards the whole block cleanly over the
  batch dim.  Expert parallelism (experts sharded over 'model' with
  all_to_all dispatch) is provided separately in ``distributed/ep.py`` as the
  hillclimb variant.
* Dispatch uses scatter-with-drop into a static (B, E, C, d) buffer — static
  shapes throughout (no ragged ops), capacity C = ceil(S*k/E * cf).
* Decode (S == 1) uses a dense masked combine over experts: with one token
  per device the cost is dominated by reading expert weights from HBM either
  way, and this keeps the step a single einsum.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain_at
from repro.models import layers as L
from repro.models.common import ModelConfig

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ModelConfig) -> Params:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, E), cfg.store_dtype) * scale},
        "gate": jax.random.normal(ks[1], (E, d, f), cfg.store_dtype) * scale,
        "up": jax.random.normal(ks[2], (E, d, f), cfg.store_dtype) * scale,
        "down": jax.random.normal(ks[3], (E, f, d), cfg.store_dtype) / math.sqrt(f),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, cfg.n_shared_experts * f, cfg)
    return p


def _router(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    """Returns (weights (B,S,k), idx (B,S,k), aux_loss scalar)."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"]["w"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss, computed per sequence then averaged.
    E = cfg.n_experts
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (B,S,k,E)
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=1)            # (B,E)
    pmean = jnp.mean(probs, axis=1)                             # (B,E)
    aux = E * jnp.mean(jnp.sum(frac * pmean, axis=-1))
    return w.astype(x.dtype), idx, aux


def capacity(cfg: ModelConfig, seq: int) -> int:
    c = math.ceil(seq * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, min(seq, int(c)))


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # Routing (cumsum over S) and dispatch must be sequence-LOCAL: under
    # meshes that shard the sequence axis (multi-pod train/prefill), gather
    # S once here (one reshard in, one out at the layer anchor) instead of
    # letting every routing op cross shards (§Perf multi-pod note:
    # 13.9 -> ~1 s collective on granite-moe 2x16x16).
    x = constrain_at(x, 0)
    w, idx, aux = _router(p, x, cfg)
    if S == 1:
        return _moe_decode(p, x, w, idx, cfg), aux

    C = capacity(cfg, S)
    # position of each (token, choice) within its expert, per sequence
    flat_e = idx.reshape(B, S * k)                              # (B,Sk)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (B,Sk,E)
    pos = jnp.cumsum(onehot, axis=1) - 1                        # (B,Sk,E)
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)             # drop slot

    # dispatch: (B, E*C, d) buffer, out-of-range scatters dropped.
    # The batch anchors are load-bearing: without them XLA's scatter
    # partitioner replicates the (B,E,C,d) buffer over the batch axes and
    # all-reduces it per layer (measured: 50%+ of train ICI traffic on the
    # MoE archs — see EXPERIMENTS.md §Perf iteration 1).
    xk = constrain_at(jnp.repeat(x, k, axis=1), 0)              # (B,Sk,d)
    dest = constrain_at(dest, 0)
    buf = constrain_at(jnp.zeros((B, E * C, d), x.dtype), 0)
    buf = jax.vmap(lambda b, dst, v: b.at[dst].add(v, mode="drop"))(
        buf, dest, xk)
    h = constrain_at(buf, 0).reshape(B, E, C, d)

    # expert MLPs (SwiGLU), batched einsum over experts
    g = jnp.einsum("becd,edf->becf", h, p["gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", h, p["up"].astype(x.dtype))
    o = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                   p["down"].astype(x.dtype))
    o = o.reshape(B, E * C, d)

    # combine: gather back (dropped -> 0) and weight
    o = constrain_at(o, 0)
    gathered = constrain_at(jax.vmap(lambda ob, dst: ob.at[dst].get(
        mode="fill", fill_value=0))(o, dest), 0)                # (B,Sk,d)
    y = jnp.sum((gathered * w.reshape(B, S * k)[..., None]
                 ).reshape(B, S, k, d), axis=2)
    if "shared" in p:
        y = y + L.mlp(p["shared"], x, cfg)
    return y, aux


def _moe_decode(p: Params, x: jnp.ndarray, w, idx, cfg: ModelConfig):
    """Dense masked combine for single-token steps (memory-bound regime)."""
    B, S, d = x.shape
    E = cfg.n_experts
    mask = jnp.sum(jax.nn.one_hot(idx, E, dtype=x.dtype) * w[..., None],
                   axis=2)                                      # (B,S,E)
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["up"].astype(x.dtype))
    o = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u,
                   p["down"].astype(x.dtype))
    y = jnp.einsum("bsed,bse->bsd", o, mask)
    if "shared" in p:
        y = y + L.mlp(p["shared"], x, cfg)
    return y
