"""Attention flavours: GQA global, sliding-window local, cross, and MLA.

Design notes
------------
* Chunked causal attention: for long sequences the query axis is processed in
  static chunks, each attending only to the (statically sliced) prefix — the
  compiled FLOPs are the exact triangular S^2/2, not the masked-dense S^2,
  and peak memory is (B, H, chunk, S) instead of (B, H, S, S).
* Sliding-window attention slices a static (window + chunk) KV band per query
  chunk — sub-quadratic in S (this is what makes recurrentgemma long-context
  capable).
* MLA (DeepSeek): training uses the naive expanded form; decode uses the
  *absorbed* form whose KV cache is the compressed latent (kv_lora + rope
  dims per token), the technique's entire point.
* All softmax statistics in f32.  Decode exposes a chunk-local form
  (``decode_attend_chunk``) returning (numerator, max, denom) so the launcher
  can combine shards across a sequence-sharded KV cache with one tiny psum
  (distributed flash-decode).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ModelConfig

Params = Dict[str, jnp.ndarray]

NEG_INF = -2.0 ** 30  # safe for f32/bf16 masks (avoid actual -inf NaN paths)
_Q_CHUNK = 2048


# ==========================================================================
# parameter init
# ==========================================================================
def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    if cfg.mla and not cross:
        return _init_mla(key, cfg)
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kv_heads = cfg.n_kv_heads
    p = {
        "wq": L.init_dense(k1, cfg.d_model, cfg.n_heads * hd, cfg),
        "wk": L.init_dense(k2, cfg.d_model, kv_heads * hd, cfg),
        "wv": L.init_dense(k3, cfg.d_model, kv_heads * hd, cfg),
        "wo": L.init_dense(k4, cfg.n_heads * hd, cfg.d_model, cfg),
    }
    return p


def _init_mla(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": L.init_dense(ks[0], cfg.d_model, H * qd, cfg),
        "w_dkv": L.init_dense(ks[1], cfg.d_model, cfg.kv_lora_rank, cfg),
        "w_kr": L.init_dense(ks[2], cfg.d_model, cfg.qk_rope_dim, cfg),
        "w_uk": L.init_dense(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_dim, cfg),
        "w_uv": L.init_dense(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim, cfg),
        "wo": L.init_dense(ks[5], H * cfg.v_head_dim, cfg.d_model, cfg),
    }


# ==========================================================================
# core attend (GQA, f32 softmax, optional softcap)
# ==========================================================================
def _scores(q, k, scale, softcap):
    # q: (B,Sq,K,G,D)  k: (B,Skv,K,D)  ->  (B,K,G,Sq,Skv)
    # Scores materialize in the COMPUTE dtype (bf16 on TPU): the MXU still
    # accumulates the dot in f32 internally, but the (Sq,Skv) score tensor —
    # the dominant HBM term of dense-attention training — is stored at
    # 2 bytes/elem (§Perf iteration 3).  Softmax row stats stay f32-safe
    # via the max-subtraction in _attend_block.
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=q.dtype) * jnp.asarray(
                       scale, q.dtype)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _attend_block(q, k, v, mask, scale, softcap):
    s = _scores(q, k, scale, softcap)
    s = jnp.where(mask, s, NEG_INF)
    # stable softmax with bf16-materialized probabilities: row stats stay
    # f32 but the (bq, Skv) probability tensor — the dominant HBM term of
    # dense-attention training (EXPERIMENTS.md §Perf iteration 2) — is
    # stored at 2 bytes/elem, exactly as flash kernels do.
    # the whole probability chain stays in the compute dtype — any f32 cast
    # here forces f32 residuals into the backward pass and doubles the
    # dominant HBM term (measured, §Perf iteration 3); the max-subtraction
    # keeps exp in (0,1] so bf16 range is safe, and the normalizer sum
    # accumulates in f32.
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1))              # (B,K,G,Sq)
    p = jnp.exp(s - m[..., None])                               # bf16 probs
    inv = 1.0 / jnp.maximum(
        jnp.sum(p, axis=-1, dtype=jnp.float32), 1e-30)          # (B,K,G,Sq)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)                 # (B,Sq,K,G,D)
    return out * inv.transpose(0, 3, 1, 2)[..., None].astype(out.dtype)


def _split_heads(x, n_heads, kv_heads):
    B, S, _ = x.shape
    return x.reshape(B, S, kv_heads, n_heads // kv_heads, -1)


def multihead_attention(q, k, v, *, q_positions, kv_positions,
                        causal: bool, window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    """q: (B,Sq,H,D); k,v: (B,Skv,K,D). Returns (B,Sq,H,Dv).

    Chunked over the query axis with static prefix/band KV slices so compiled
    FLOPs match the true masked workload.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, K, H // K, D)

    def block(qc, kc, vc, qpos, kpos):
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
        if causal:
            m = qpos[:, None] >= kpos[None, :]
        if window:
            m &= (qpos[:, None] - kpos[None, :]) < window
        out = _attend_block(qc, kc, vc, m[None, None, None], scale, softcap)
        return out

    Skv = k.shape[1]
    if Sq <= _Q_CHUNK or not causal:
        out = block(qg, k, v, q_positions, kv_positions)
        return out.reshape(B, Sq, H, -1)

    # --- triangular / banded chunking (static python loop) ----------------
    chunk = _Q_CHUNK
    n_chunks = math.ceil(Sq / chunk)
    outs = []
    for i in range(n_chunks):
        q0, q1 = i * chunk, min((i + 1) * chunk, Sq)
        if window:
            k0 = max(0, q0 - (window - 1))
        else:
            k0 = 0
        k1 = min(q1, Skv)
        qc = qg[:, q0:q1]
        outs.append(block(qc, k[:, k0:k1], v[:, k0:k1],
                          q_positions[q0:q1], kv_positions[k0:k1]))
    return jnp.concatenate(outs, axis=1).reshape(B, Sq, H, -1)


# ==========================================================================
# standard (GQA) attention layer: train / prefill / decode
# ==========================================================================
def attn_forward(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, *, causal: bool = True, window: int = 0,
                 kv_source: Optional[jnp.ndarray] = None,
                 kv_positions: Optional[jnp.ndarray] = None,
                 return_kv: bool = False):
    """Full-sequence attention (training / prefill).  kv_source enables
    cross-attention (encoder output)."""
    if cfg.mla and kv_source is None:
        return _mla_forward(p, x, positions, cfg, return_kv=return_kv)
    hd = cfg.hd
    src = x if kv_source is None else kv_source
    kv_positions = positions if kv_positions is None else kv_positions
    q = dense3(p["wq"], x, cfg.n_heads, hd)
    k = dense3(p["wk"], src, cfg.n_kv_heads, hd)
    v = dense3(p["wv"], src, cfg.n_kv_heads, hd)
    if kv_source is None and cfg.pos_kind == "rope":  # self-attention RoPE
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, kv_positions, cfg.rope_theta)
    out = multihead_attention(
        q, k, v, q_positions=positions, kv_positions=kv_positions,
        causal=causal and kv_source is None, window=window,
        softcap=cfg.attn_softcap)
    y = L.dense(p["wo"], out.reshape(*x.shape[:-1], -1))
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def dense3(p: Params, x: jnp.ndarray, heads: int, hd: int) -> jnp.ndarray:
    y = L.dense(p, x)
    return y.reshape(*x.shape[:-1], heads, hd)


def init_cache_attn(cfg: ModelConfig, batch: int, cache_len: int, *,
                    window: int = 0, dtype=None) -> Dict[str, jnp.ndarray]:
    """Zeroed KV cache entry for one attention layer."""
    dtype = dtype or cfg.compute_dtype
    S = min(cache_len, window) if window else cache_len
    if cfg.mla:
        return {
            "ckv": jnp.zeros((batch, S, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, S, cfg.qk_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.hd), dtype),
    }


def decode_attend_chunk(q, k, v, q_pos, kv_pos, *, scale, softcap=0.0,
                        window: int = 0):
    """One-token attention over a KV chunk, returning combinable stats.

    q: (B,H,D); k,v: (B,S,K,D); kv_pos: (B,S) absolute positions (< 0 or
    > q_pos entries are masked).  Returns (num (B,H,Dv), mx (B,H), den (B,H)).
    Shards of a sequence-partitioned cache combine via ``combine_decode``.
    """
    B, H, D = q.shape
    K = k.shape[2]
    qg = q.reshape(B, K, H // K, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window:
        valid &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1)                                   # (B,K,G)
    w = jnp.exp(s - mx[..., None])
    den = jnp.sum(w, axis=-1)
    num = jnp.einsum("bkgs,bskd->bkgd", w.astype(v.dtype), v)
    return (num.reshape(B, H, -1), mx.reshape(B, H), den.reshape(B, H))


def combine_decode(parts):
    """Combine per-chunk (num, mx, den) stats -> (B,H,Dv) output."""
    nums, mxs, dens = zip(*parts)
    mx = jnp.max(jnp.stack(mxs), axis=0)                       # (B,H)
    out_num = 0.0
    out_den = 0.0
    for n, m, d in parts:
        c = jnp.exp(m - mx)
        out_num = out_num + n.astype(jnp.float32) * c[..., None]
        out_den = out_den + d * c
    return (out_num / jnp.maximum(out_den, 1e-37)[..., None])


def attn_decode(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                pos: jnp.ndarray, cfg: ModelConfig, *, window: int = 0):
    """Single-token decode.  x: (B,1,d); pos: (B,) absolute position.
    Returns (y (B,1,d), new_cache)."""
    if cfg.mla:
        return _mla_decode(p, x, cache, pos, cfg)
    hd = cfg.hd
    B = x.shape[0]
    q = dense3(p["wq"], x, cfg.n_heads, hd)[:, 0]              # (B,H,D)
    k1 = dense3(p["wk"], x, cfg.n_kv_heads, hd)[:, 0]
    v1 = dense3(p["wv"], x, cfg.n_kv_heads, hd)[:, 0]
    if cfg.pos_kind == "rope":
        q = L.apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k1 = L.apply_rope(k1[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    S = cache["k"].shape[1]
    slot = (pos % S) if window else pos                        # ring buffer
    k = _cache_insert(cache["k"], k1, slot)
    v = _cache_insert(cache["v"], v1, slot)
    kv_pos = _cache_positions(pos, S, window)
    stats = decode_attend_chunk(q, k, v, pos, kv_pos,
                                scale=1.0 / math.sqrt(hd),
                                softcap=cfg.attn_softcap, window=window)
    out = combine_decode([stats]).astype(x.dtype)
    y = L.dense(p["wo"], out.reshape(B, 1, -1)[:, 0])[:, None]
    return y, {"k": k, "v": v}


def _cache_insert(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray):
    """Insert per-batch row `new` at per-batch index `slot` (vmap'd)."""
    return jax.vmap(lambda b, n, s: jax.lax.dynamic_update_index_in_dim(
        b, n.astype(b.dtype), s, 0))(buf, new, slot)


def _cache_positions(pos: jnp.ndarray, S: int, window: int) -> jnp.ndarray:
    """Absolute position of every cache slot; -1 marks unwritten slots."""
    idx = jnp.arange(S)[None, :]                               # (1,S)
    if window:
        # slot s holds the most recent position p with p % S == s, p <= pos
        cur = pos[:, None]
        cand = cur - ((cur % S) - idx) % S
        return jnp.where(cand >= 0, cand, -1)
    return jnp.where(idx <= pos[:, None], idx, -1)


# ==========================================================================
# MLA
# ==========================================================================
def _mla_qkr(p, x, positions, cfg):
    H = cfg.n_heads
    q = dense3(p["wq"], x, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_forward(p, x, positions, cfg, *, return_kv=False):
    """Naive (expanded) MLA for training/prefill."""
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_qkr(p, x, positions, cfg)
    ckv = L.dense(p["w_dkv"], x)                               # (B,S,R)
    kr = L.dense(p["w_kr"], x).reshape(B, S, 1, cfg.qk_rope_dim)
    kr = L.apply_rope(kr, positions, cfg.rope_theta)           # shared head
    k_nope = L.dense(p["w_uk"], ckv).reshape(B, S, H, cfg.qk_nope_dim)
    v = L.dense(p["w_uv"], ckv).reshape(B, S, H, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr, (B, S, H, cfg.qk_rope_dim))],
                        axis=-1)
    out = multihead_attention(q, k, v, q_positions=positions,
                              kv_positions=positions, causal=True)
    y = L.dense(p["wo"], out.reshape(B, S, -1))
    if return_kv:
        return y, {"ckv": ckv, "kr": kr[:, :, 0]}
    return y


def _mla_decode(p, x, cache, pos, cfg):
    """Absorbed MLA decode: scores live in the compressed latent space, the
    cache holds only (kv_lora_rank + rope) floats per token."""
    B = x.shape[0]
    H, R = cfg.n_heads, cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _mla_qkr(p, x, pos[:, None], cfg)         # (B,1,H,*)
    # absorb W_uk:  q_lat[b,h,r] = sum_d q_nope[b,h,d] * W_uk[r, h*d]
    w_uk = p["w_uk"]["w"].reshape(R, H, cfg.qk_nope_dim).astype(x.dtype)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    ckv1 = L.dense(p["w_dkv"], x)[:, 0]                        # (B,R)
    kr1 = L.dense(p["w_kr"], x)                                # (B,1,rope)
    kr1 = L.apply_rope(kr1[:, :, None], pos[:, None], cfg.rope_theta)[:, 0, 0]
    ckv = _cache_insert(cache["ckv"], ckv1, pos)
    kr = _cache_insert(cache["kr"], kr1, pos)
    S = ckv.shape[1]
    kv_pos = _cache_positions(pos, S, 0)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhe,bse->bhs", q_rope[:, 0].astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhs,bsr->bhr", prob, ckv)            # (B,H,R)
    w_uv = p["w_uv"]["w"].reshape(R, H, cfg.v_head_dim).astype(x.dtype)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, w_uv)
    y = L.dense(p["wo"], out.reshape(B, 1, -1)[:, 0])[:, None]
    return y, {"ckv": ckv, "kr": kr}
