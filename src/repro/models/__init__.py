"""Model substrate: unified LM over all assigned architecture families."""
from repro.models.common import ModelConfig, ShapeSpec, SHAPES, shape_applicable
from repro.models.model import LM

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_applicable", "LM"]
