"""Model configuration shared by every architecture in the zoo.

A single frozen dataclass describes all ten assigned architectures; family-
specific fields are simply unused by other families.  Configs are pure data —
they can be hashed, serialized into the experiment store, and reduced to smoke
size for CPU tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

# Layer kinds used in ``block_pattern`` (heterogeneous stacks).
ATTN = "attn"            # global causal attention
LOCAL_ATTN = "local"     # sliding-window attention
RGLRU = "rglru"          # Griffin recurrent block
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | encdec | vlm | ssm | hybrid | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention flavour ------------------------------------------------
    pos_kind: str = "rope"           # rope | sincos | none
    scale_embed: bool = False        # multiply embeddings by sqrt(d_model)
    rope_theta: float = 10_000.0
    window: int = 0                  # sliding-window size for LOCAL_ATTN
    logit_softcap: float = 0.0       # final-logit softcap (gemma-style), 0=off
    attn_softcap: float = 0.0        # attention-logit softcap, 0=off
    parallel_block: bool = False     # cohere-style parallel attn+FFN residual

    # --- MLA (DeepSeek multi-head latent attention) -----------------------
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0      # leading layers that use a dense FFN
    dense_d_ff: int = 0              # d_ff for those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- heterogeneous stacks (ssm / hybrid) -------------------------------
    block_pattern: Tuple[str, ...] = ()   # repeated; remainder handled exactly
    d_rnn: int = 0                   # recurrent width (RG-LRU / xLSTM)
    conv_width: int = 4              # temporal conv width in recurrent blocks

    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed-frame count (stub frontend)

    # --- vlm ----------------------------------------------------------------
    n_img_tokens: int = 0            # precomputed-patch count (stub frontend)

    # --- plumbing -----------------------------------------------------------
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"     # storage dtype
    remat: str = "full"              # none | dots | full
    scan_layers: bool = True         # scan over homogeneous layer groups
    use_pallas: bool = False         # route attention through Pallas kernels

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def store_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Full per-layer kind list (length == n_layers)."""
        if not self.block_pattern:
            return (ATTN,) * self.n_layers
        reps = math.ceil(self.n_layers / len(self.block_pattern))
        return tuple((self.block_pattern * reps)[: self.n_layers])

    def layer_groups(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Partition the stack into homogeneous repeating groups for scan.

        Returns ((pattern, repeat), ...) with sum(len(p)*r) == n_layers and
        the original interleaving preserved.  A uniform stack yields a single
        group; recurrentgemma's 26 layers yield 8x(R,R,A) + 2x(R,).
        """
        pat = self.pattern
        if not self.block_pattern:
            return (((ATTN,), self.n_layers),)
        p = self.block_pattern
        full, rem = divmod(self.n_layers, len(p))
        groups = []
        if full:
            groups.append((p, full))
        if rem:
            groups.append((tuple(pat[len(p) * full:]), 1))
        return tuple(groups)

    def is_subquadratic(self) -> bool:
        """True when no layer requires a full-length attention cache."""
        return all(k in (RGLRU, MLSTM, SLSTM, LOCAL_ATTN) for k in self.pattern)

    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    # -------------------------------------------------------------- counts
    def param_count(self) -> int:
        """Exact parameter count (matches init); used for 6ND model FLOPs."""
        from repro.models import model as _model  # lazy, avoids cycle
        import jax

        shapes = _model.LM(self).param_shapes(deduped=True)
        return int(sum(math.prod(s.shape) for s in jax.tree.leaves(shapes)))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k + shared experts)."""
        total = self.param_count()
        if not self.moe:
            return total
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.top_k) * per_expert * n_moe_layers
        return total - inactive

    # -------------------------------------------------------------- smoke
    def reduced(self, **overrides: Any) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat = self.block_pattern
        base: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=max(2, len(pat)) if pat else 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=257,
            window=min(self.window, 32) if self.window else 0,
            d_rnn=64 if self.d_rnn else 0,
            n_experts=min(self.n_experts, 4) if self.moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.moe else 0,
            d_ff_expert=64 if self.moe else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            dense_d_ff=128 if self.dense_d_ff else 0,
            kv_lora_rank=32 if self.mla else 0,
            qk_nope_dim=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            n_img_tokens=8 if self.n_img_tokens else 0,
            param_dtype="float32",
            dtype="float32",
            remat="none",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; else the documented skip."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, ("skip: pure full-attention arch has no sub-quadratic "
                       "mode for 524k context (see DESIGN.md)")
    return True, ""
