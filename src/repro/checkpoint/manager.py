"""Sharded npz checkpoints with async writes and atomic step directories.

Fault-tolerance contract (trial-level of DESIGN.md §7):
* a checkpoint directory becomes visible only after a complete atomic
  rename, so a crash mid-write can never produce a half checkpoint;
* ``latest_step`` scans for the newest complete step — restart just works;
* writes happen on a background thread (training never blocks on disk);
* ``keep`` bounds disk usage (old steps garbage-collected).

Pytrees are flattened to name->array with jax.tree_util key paths, stored as
one npz per host shard (this container: one shard).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(tree, path: pathlib.Path) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(template, path: pathlib.Path):
    """Restore into the structure of ``template`` (shape/dtype checked)."""
    data = np.load(path, allow_pickle=False)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_t, leaf in flat_t:
        key = "/".join(_path_str(p) for p in path_t)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- write
    def save(self, step: int, state, metadata: Optional[Dict] = None) -> None:
        self.wait()  # one in-flight write at a time
        # device->host copy happens NOW so training can mutate state after
        host_state = jax.tree.map(np.asarray, state)

        def write():
            tmp = self.dir / f".tmp-{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            save_pytree(host_state, tmp / "state.npz")
            (tmp / "meta.json").write_text(json.dumps(
                {"step": step, **(metadata or {})}))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)            # atomic visibility
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------- read
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "state.npz").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        state = load_pytree(template, d / "state.npz")
        meta = json.loads((d / "meta.json").read_text())
        return state, meta
