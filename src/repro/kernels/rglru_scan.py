"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t*h_{t-1}+b_t.

TPU adaptation: the recurrence is elementwise over the feature dim, so the
natural layout is feature tiles resident in VMEM while TIME is the
innermost sequential grid axis; the hidden state lives in VMEM scratch
across time tiles (zero HBM traffic for the carry).  Within a (bt, bf) tile
the time loop is a fori over rows — bandwidth-bound as expected, so tiles
are sized to stream log_a/b at full HBM rate: (bt, bf) = (256, 512) f32
-> 1 MB/operand in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, b_ref, o_ref, h_scr, *, bt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(i, h):
        la = la_ref[0, i, :]
        bb = b_ref[0, i, :]
        h = h * jnp.exp(la) + bb
        o_ref[0, i, :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, bt, step, h_scr[...])


@functools.partial(jax.jit,
                   static_argnames=("bt", "bf", "interpret"))
def rglru_scan(log_a, b, *, bt: int = 256, bf: int = 512,
               interpret: bool = False):
    """log_a, b: (B,S,R) f32 -> h (B,S,R) f32."""
    B, S, R = log_a.shape
    bt_ = min(bt, S)
    bf_ = min(bf, R)
    pad_t = (-S) % bt_
    pad_f = (-R) % bf_
    if pad_t or pad_f:
        padc = ((0, 0), (0, pad_t), (0, pad_f))
        log_a = jnp.pad(log_a, padc)      # exp(0)=1, b=0 -> state invariant
        b = jnp.pad(b, padc)
    St, Rt = log_a.shape[1], log_a.shape[2]
    # grid: time INNERMOST so the VMEM carry is sequential-correct
    grid = (B, Rt // bf_, St // bt_)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, bt=bt_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt_, bf_), lambda bi, fi, ti: (bi, ti, fi)),
            pl.BlockSpec((1, bt_, bf_), lambda bi, fi, ti: (bi, ti, fi)),
        ],
        out_specs=pl.BlockSpec((1, bt_, bf_),
                               lambda bi, fi, ti: (bi, ti, fi)),
        out_shape=jax.ShapeDtypeStruct(log_a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((bf_,), jnp.float32)],
        interpret=interpret,
    )(log_a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :S, :R]
