"""jit'd public wrappers for the Pallas kernels.

On TPU these dispatch to the pallas_call kernels; elsewhere (this CPU
container, unit tests) they run the kernels in interpret mode or fall back
to the jnp oracle — callers never branch on platform themselves.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_quant import int8_quantize as _quant
from repro.kernels.rglru_scan import rglru_scan as _rglru


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    force_kernel=False):
    if _on_tpu() or force_kernel:
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)


def rglru_scan(log_a, b, *, force_kernel=False):
    if _on_tpu() or force_kernel:
        return _rglru(log_a, b, interpret=not _on_tpu())
    return ref.rglru_scan_ref(log_a, b)


def int8_quantize(x, *, force_kernel=False):
    if _on_tpu() or force_kernel:
        return _quant(x, interpret=not _on_tpu())
    return ref.int8_quant_ref(x)
