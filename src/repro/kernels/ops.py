"""jit'd public wrappers for the Pallas kernels.

On TPU these dispatch to the pallas_call kernels; elsewhere (this CPU
container, unit tests) they run the kernels in interpret mode or fall back
to the jnp oracle — callers never branch on platform themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import gp as _gpk
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_quant import int8_quantize as _quant
from repro.kernels.rglru_scan import rglru_scan as _rglru


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    force_kernel=False):
    if _on_tpu() or force_kernel:
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)


def rglru_scan(log_a, b, *, force_kernel=False):
    if _on_tpu() or force_kernel:
        return _rglru(log_a, b, interpret=not _on_tpu())
    return ref.rglru_scan_ref(log_a, b)


def int8_quantize(x, *, force_kernel=False):
    if _on_tpu() or force_kernel:
        return _quant(x, interpret=not _on_tpu())
    return ref.int8_quant_ref(x)


def gp_neg_mll(log_ls, log_amp, log_noise, x, y, mask, *,
               force_kernel=False):
    """Batched masked GP neg-MLL over lanes (ISSUE 8): fused Pallas
    Cholesky+solve+logdet with an analytic custom_vjp on TPU, plain
    differentiable jnp on CPU.  Shapes: log_ls (k,d), log_amp (k,),
    log_noise (k,), x (k,b,d), y (k,b), mask (k,b) -> nll (k,)."""
    if _on_tpu() or force_kernel:
        return _gpk.gp_nll(log_ls, log_amp, log_noise, x, y, mask,
                           interpret=not _on_tpu())
    return ref.gp_nll_ref(log_ls, log_amp, log_noise, x, y, mask)


def gp_fit_grads(log_ls, log_amp, log_noise, x, y, mask, *,
                 force_kernel=False):
    """Per-lane NLL hyperparameter gradients for the batched Adam fit
    loop (``gp._fit_lanes``).  On TPU this differentiates the fused
    Pallas ``gp_nll`` (its custom_vjp reuses the kernel's Cholesky/solve
    residuals); on CPU it runs the GEMM-rich analytic adjoint directly
    — cheaper per lane than autodiff through ``jnp.linalg.cholesky``.
    Returns (g_log_ls (k,d), g_log_amp (k,), g_log_noise (k,))."""
    if _on_tpu() or force_kernel:
        def nll_sum(ll, la, ln):
            return jnp.sum(_gpk.gp_nll(ll, la, ln, x, y, mask,
                                       interpret=not _on_tpu()))
        return jax.grad(nll_sum, argnums=(0, 1, 2))(
            log_ls, log_amp, log_noise)
    return ref.gp_nll_grads_ref(log_ls, log_amp, log_noise, x, y, mask)


def gp_ei(log_ls, log_amp, x, mask, chol, alpha, y_mean, y_std, cand,
          best, *, xi=0.01, force_kernel=False):
    """Batched expected improvement over per-lane posteriors (ISSUE 8).
    Shapes as in ``ref.gp_ei_ref`` -> ei (k,m) in raw y units."""
    if _on_tpu() or force_kernel:
        return _gpk.gp_ei(log_ls, log_amp, x, mask, chol, alpha, y_mean,
                          y_std, cand, best, xi=xi,
                          interpret=not _on_tpu())
    return ref.gp_ei_ref(log_ls, log_amp, x, mask, chol, alpha, y_mean,
                         y_std, cand, best, xi=xi)
