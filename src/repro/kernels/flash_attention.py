"""Pallas TPU flash attention (causal, GQA, optional sliding window).

TPU-native design (hardware adaptation per DESIGN.md):
* grid = (batch, q_heads, Sq/bq, Skv/bk) with the KV axis innermost — the
  sequential TPU grid carries the online-softmax state (m, l, acc) in VMEM
  scratch across KV tiles; output is written once on the final tile.
* BlockSpec tiling keeps one (bq, d) query tile, one (bk, d) KV tile, and
  the (bq, bk) score tile in VMEM; bq/bk default to 128/256 — multiples of
  the 128-wide MXU systolic dims, and a working set of
  (bq*d + 2*bk*d + bq*bk) * 4B ~ 0.6 MB for d=128, far under the ~16 MB
  VMEM budget, leaving room for double buffering.
* GQA is free: the KV BlockSpec index map folds q-head h onto kv-head
  h // (H/K), so no head replication ever materializes.
* Fully-masked KV tiles (beyond the causal frontier or outside the local
  window) are skipped with @pl.when — compiled FLOPs match the triangular/
  banded workload like the XLA path in models/attention.py.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, scale: float, causal: bool, window: int,
                  seq_q: int, seq_kv: int, softcap: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile visibility: skip tiles that the causal frontier / window excludes
    first_q = iq * bq
    last_q = first_q + bq - 1
    first_k = ik * bk
    last_k = first_k + bk - 1
    visible = True
    if causal:
        visible = jnp.asarray(first_k <= last_q)
    if window:
        visible = jnp.logical_and(visible,
                                  jnp.asarray(last_k >= first_q - window + 1))

    @pl.when(visible)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = (kv_pos < seq_kv) & (q_pos < seq_q)
        if causal:
            mask &= q_pos >= kv_pos
        if window:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 256,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k,v: (B, Skv, K, D) with K | H. -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    assert H % K == 0, "GQA requires kv_heads | q_heads"
    group = H // K
    scale = 1.0 / math.sqrt(D)

    bq_ = min(bq, max(Sq, 8))
    bk_ = min(bk, max(Skv, 8))
    # pad sequences up to tile multiples (masked out inside the kernel)
    pad_q = (-Sq) % bq_
    pad_k = (-Skv) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    grid = (B, H, q.shape[1] // bq_, k.shape[1] // bk_)
    kernel = functools.partial(
        _flash_kernel, bq=bq_, bk=bk_, scale=scale, causal=causal,
        window=window, seq_q=Sq, seq_kv=Skv, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk_, 1, D),
                         lambda b, h, i, j, g=group: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk_, 1, D),
                         lambda b, h, i, j, g=group: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # VMEM online-softmax state, carried across KV tiles
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :Sq]
    return out
