"""Pallas TPU kernel: blockwise max-abs int8 quantization.

Hot path of the error-feedback compressed gradient all-reduce
(distributed/compress.py).  One grid step quantizes a (rows, BLOCK) tile:
reduction + scale + round stay in VMEM/VREGs, quantized bytes stream back
to HBM at 1/4 the input bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rows, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def int8_quantize(x, *, rows: int = 256, interpret: bool = False):
    """x: any shape -> (q int8 (nb, BLOCK), scales f32 (nb,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    nb = blocks.shape[0]
    rows_ = min(rows, nb)
    pad_r = (-nb) % rows_
    if pad_r:
        blocks = jnp.pad(blocks, ((0, pad_r), (0, 0)))
    grid = (blocks.shape[0] // rows_,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows_, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((rows_,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(blocks.shape, jnp.int8),
                   jax.ShapeDtypeStruct((blocks.shape[0],), jnp.float32)],
        interpret=interpret,
    )(blocks)
    return q[:nb], s[:nb]
