"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,Sq,H,D); k,v: (B,Skv,K,D) -> (B,Sq,H,D).  Dense masked softmax
    attention in f32 (the thing flash attention must equal exactly)."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rglru_scan_ref(log_a, b, h0=None):
    """Sequential RG-LRU recurrence oracle.
    log_a, b: (B,S,R) f32; h0: (B,R) -> h: (B,S,R)."""
    B, S, R = log_a.shape
    h = jnp.zeros((B, R), jnp.float32) if h0 is None else h0

    def step(h, xs):
        la, bb = xs
        h = h * jnp.exp(la) + bb
        return h, h

    _, hs = jax.lax.scan(step, h,
                         (log_a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def _matern52(a, b, log_ls, log_amp):
    """Matérn-5/2 ARD cross-covariance (mirrors core/suggest/gp.py —
    kernels/ must not import core, so the formulas are duplicated here
    and pinned by parity tests)."""
    ls = jnp.exp(log_ls)
    amp2 = jnp.exp(2.0 * log_amp)
    a = a / ls
    b = b / ls
    sq = jnp.maximum(
        jnp.sum(a * a, -1)[:, None] - 2 * a @ b.T + jnp.sum(b * b, -1)[None],
        0.0)
    r = jnp.sqrt(sq + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    return amp2 * (1 + s5r + 5.0 / 3.0 * r * r) * jnp.exp(-s5r)


def gp_nll_ref(log_ls, log_amp, log_noise, x, y, mask):
    """Batched masked GP negative log marginal likelihood oracle.

    log_ls (k,d), log_amp (k,), log_noise (k,), x (k,b,d), y (k,b),
    mask (k,b) -> nll (k,).  Padded rows carry an identity block in the
    covariance so each lane's value is independent of the bucket size.
    Pure differentiable jnp — this is both the CPU fallback of
    ``ops.gp_neg_mll`` and the allclose ground truth for the Pallas
    kernel."""
    def one(ll, la, ln, xs, ys, ms):
        b = xs.shape[0]
        noise2 = jnp.exp(2.0 * ln) + 1e-5
        k = _matern52(xs, xs, ll, la) + noise2 * jnp.eye(b)
        mm = ms[:, None] * ms[None, :]
        k = k * mm + jnp.diag(1.0 - ms)
        chol = jnp.linalg.cholesky(k)
        ym = ys * ms
        alpha = jax.scipy.linalg.cho_solve((chol, True), ym)
        return (0.5 * ym @ alpha
                + jnp.sum(jnp.log(jnp.diagonal(chol)))
                + 0.5 * jnp.sum(ms) * jnp.log(2 * jnp.pi))
    return jax.vmap(one)(log_ls, log_amp, log_noise, x, y, mask)


def gp_nll_grads_ref(log_ls, log_amp, log_noise, x, y, mask):
    """Per-lane gradients of ``gp_nll_ref`` w.r.t. the hyperparameters —
    the analytic adjoint dNLL/dθ = tr(S·∂K/∂θ), S = ½(K⁻¹ − αα'),
    written batched and GEMM-rich so one CPU core amortizes across
    lanes: one Cholesky + one triangular solve per lane per call, the
    b³ remainder (K⁻¹ assembly) and every kernel-derivative contraction
    expressed as batched matmuls instead of per-element einsums over a
    (k,b,b,d) tensor.  This is what makes ``gp.batched_fit`` beat k
    serial autodiff fits on the host (ISSUE 8); on TPU the same math
    runs as the Pallas ``gp_nll`` custom_vjp.

    Shapes as in ``gp_nll_ref`` -> (g_log_ls (k,d), g_log_amp (k,),
    g_log_noise (k,)).  All-zero-mask lanes get exactly zero grads."""
    k, b, d = x.shape
    ls = jnp.exp(log_ls)                                  # (k,d)
    amp2 = jnp.exp(2.0 * log_amp)                         # (k,)
    noise2 = jnp.exp(2.0 * log_noise) + 1e-5              # (k,)
    xa = x / ls[:, None, :]                               # (k,b,d)
    q = jnp.sum(xa * xa, -1)                              # (k,b)
    sq = jnp.maximum(q[:, :, None]
                     - 2.0 * jnp.einsum("kid,kjd->kij", xa, xa)
                     + q[:, None, :], 0.0)
    r = jnp.sqrt(sq + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    e = jnp.exp(-s5r)
    mat = amp2[:, None, None] * (1.0 + s5r + (5.0 / 3.0) * r * r) * e
    mm = mask[:, :, None] * mask[:, None, :]
    eye = jnp.eye(b, dtype=x.dtype)
    cov = (mat + noise2[:, None, None] * eye) * mm \
        + (1.0 - mask)[:, :, None] * eye
    L = jnp.linalg.cholesky(cov)
    linv = jax.scipy.linalg.solve_triangular(
        L, jnp.broadcast_to(eye, (k, b, b)), lower=True)
    ki = jnp.einsum("kji,kjl->kil", linv, linv)           # K⁻¹ = L⁻ᵀL⁻¹
    alpha = jnp.einsum("kij,kj->ki", ki, y * mask)
    S = 0.5 * (ki - alpha[:, :, None] * alpha[:, None, :])
    W = S * mm
    # ∂k/∂log_ls_d = amp2·(5/3)(1+√5r)e^{−√5r}·(xa_id − xa_jd)²; V is
    # symmetric, so Σ_ij V_ij(xa_id−xa_jd)² folds into one V@xa matmul
    V = W * (amp2[:, None, None] * (5.0 / 3.0) * (1.0 + s5r) * e)
    rs = jnp.sum(V, axis=2)                               # (k,b)
    vxa = jnp.einsum("kij,kjd->kid", V, xa)
    g_ll = 2.0 * (jnp.einsum("ki,kid->kd", rs, xa * xa)
                  - jnp.einsum("kid,kid->kd", xa, vxa))
    g_la = 2.0 * jnp.sum(W * mat, axis=(1, 2))
    g_ln = 2.0 * jnp.exp(2.0 * log_noise) * jnp.sum(
        jnp.diagonal(S, axis1=1, axis2=2) * mask, axis=1)
    return g_ll, g_la, g_ln


def gp_ei_ref(log_ls, log_amp, x, mask, chol, alpha, y_mean, y_std,
              cand, best, xi=0.01):
    """Batched expected-improvement oracle over per-lane posteriors.

    log_ls (k,d), log_amp (k,), x (k,b,d), mask (k,b), chol (k,b,b),
    alpha (k,b), y_mean (k,), y_std (k,), cand (k,m,d), best (k,)
    -> ei (k,m) in raw y units (mirrors gp.predict + expected_improvement)."""
    def one(ll, la, xs, ms, L, al, ymn, ystd, cq, bb):
        kq = _matern52(cq, xs, ll, la) * ms[None, :]          # (m,b)
        mu = kq @ al
        v = jax.scipy.linalg.solve_triangular(L, kq.T, lower=True)
        amp2 = jnp.exp(2.0 * la)
        var = jnp.maximum(amp2 - jnp.sum(v * v, axis=0), 1e-12)
        mu = mu * ystd + ymn
        sd = jnp.sqrt(var) * ystd
        z = (mu - bb - xi) / sd
        ncdf = 0.5 * (1 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
        npdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2 * jnp.pi)
        return (mu - bb - xi) * ncdf + sd * npdf
    return jax.vmap(one)(log_ls, log_amp, x, mask, chol, alpha,
                         y_mean, y_std, cand, best)


def int8_quant_ref(x, block=256):
    """Blockwise max-abs int8 quantization oracle.
    x: any shape -> (q int8 (nb, block), scales f32 (nb,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale
