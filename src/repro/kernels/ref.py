"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,Sq,H,D); k,v: (B,Skv,K,D) -> (B,Sq,H,D).  Dense masked softmax
    attention in f32 (the thing flash attention must equal exactly)."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rglru_scan_ref(log_a, b, h0=None):
    """Sequential RG-LRU recurrence oracle.
    log_a, b: (B,S,R) f32; h0: (B,R) -> h: (B,S,R)."""
    B, S, R = log_a.shape
    h = jnp.zeros((B, R), jnp.float32) if h0 is None else h0

    def step(h, xs):
        la, bb = xs
        h = h * jnp.exp(la) + bb
        return h, h

    _, hs = jax.lax.scan(step, h,
                         (log_a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def int8_quant_ref(x, block=256):
    """Blockwise max-abs int8 quantization oracle.
    x: any shape -> (q int8 (nb, block), scales f32 (nb,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale
