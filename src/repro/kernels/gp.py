"""Pallas TPU kernels for the batched GP fit path (ISSUE 8).

Two kernels, both with a *lane* (= experiment) grid axis so k same-bucket
experiments run in one dispatch:

* ``gp_nll`` — masked batched negative log marginal likelihood: the
  covariance build, Cholesky factorization, triangular solve, and logdet
  are fused into ONE kernel per lane.  The Cholesky is a right-looking
  rank-1 update loop expressed entirely in ops Pallas can lower on TPU
  (dot / where / broadcasted_iota / reductions — no lax.linalg inside the
  kernel); identity-padding rows are masked in-kernel, so a lane's value
  is independent of its bucket's padding.  Gradients come from a
  ``custom_vjp``: the forward kernel also emits its (L, z) residuals and
  the backward pass is the *analytic* adjoint tr(S·∂K/∂θ) with
  S = ½(K⁻¹ − αα') in plain jnp — cheaper than autodiff through a
  Cholesky, and shared by the TPU and interpret paths.

* ``gp_ei`` — batched expected improvement: per lane, the cross
  covariance, the forward triangular solve for the predictive variance,
  and the EI closed form run fused over the candidate pool.

The TPU Cholesky loop: at step j, with e_j the one-hot column,
``col = A e_j`` is column j of the trailing matrix, ``l = col/√(A_jj)``
masked to rows ≥ j is column j of L, and ``A ← A − l l'`` performs the
rank-1 trailing update.  Masked (padded) rows hold an identity block in
A, so they factor to e_j columns with unit diagonal — log det and the
quadratic form see exactly the real rows.

Gradient cotangents are exact for the hyperparameters and y; ``x`` and
``mask`` cotangents are zero (the fit loop never differentiates them).

CPU callers go through ``ops.gp_neg_mll`` / ``ops.gp_ei`` which dispatch
to the jnp oracles in ``ref.py`` instead; these kernels run under
``interpret=True`` only in tests (parity vs ref, atol 1e-5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LOG_2PI = 1.8378770664093453


def _eye(b):
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    return (rows == cols).astype(jnp.float32)


def _masked_cov_block(ll, la, ln, x, m, b):
    """Masked Matérn-5/2 covariance for one lane — identical math to
    ``core.suggest.gp._masked_cov`` (pinned by parity tests)."""
    ls = jnp.exp(ll)                               # (d,)
    amp2 = jnp.exp(2.0 * la)
    noise2 = jnp.exp(2.0 * ln) + 1e-5
    xs = x / ls[None, :]                           # (b,d)
    s = jnp.sum(xs * xs, axis=1, keepdims=True)    # (b,1)
    sq = jnp.maximum(
        s - 2.0 * jnp.dot(xs, xs.T, preferred_element_type=jnp.float32)
        + s.T, 0.0)
    r = jnp.sqrt(sq + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    k = amp2 * (1.0 + s5r + (5.0 / 3.0) * r * r) * jnp.exp(-s5r)
    eye = _eye(b)
    k = k + noise2 * eye
    mm = m * m.T                                   # (b,b)
    return k * mm + eye * (1.0 - m), eye


def _chol_loop(K, b):
    """Right-looking Cholesky via b one-hot rank-1 updates (TPU-lowerable:
    dot / where / iota only).  Returns lower-triangular L."""
    idx = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)

    def step(j, carry):
        A, L = carry
        ej = (idx == j).astype(jnp.float32)                       # (b,1)
        col = jnp.dot(A, ej, preferred_element_type=jnp.float32)  # (b,1)
        dj = jnp.maximum(jnp.sum(col * ej), 1e-10)
        l = jnp.where(idx >= j, col / jnp.sqrt(dj), 0.0)
        L = L + jnp.dot(l, ej.T, preferred_element_type=jnp.float32)
        A = A - jnp.dot(l, l.T, preferred_element_type=jnp.float32)
        return A, L

    _, L = jax.lax.fori_loop(0, b, step, (K, jnp.zeros_like(K)))
    return L, idx


def _fwd_solve(L, rhs, idx, b):
    """Forward substitution z = L^{-1} rhs for a (b,m) right-hand side,
    one one-hot masked step per row."""
    diag = jnp.sum(L * _eye(b), axis=1, keepdims=True)            # (b,1)

    def step(j, carry):
        z, acc = carry
        ej = (idx == j).astype(jnp.float32)                       # (b,1)
        ljj = jnp.sum(diag * ej)
        row = jnp.sum(ej * (rhs - acc), axis=0, keepdims=True) / ljj
        z = z + jnp.dot(ej, row, preferred_element_type=jnp.float32)
        acc = acc + jnp.dot(
            jnp.dot(L, ej, preferred_element_type=jnp.float32), row,
            preferred_element_type=jnp.float32)
        return z, acc

    z, _ = jax.lax.fori_loop(
        0, b, step, (jnp.zeros_like(rhs), jnp.zeros_like(rhs)))
    return z, diag


# ------------------------------------------------------------------ NLL
def _nll_kernel(ll_ref, la_ref, ln_ref, x_ref, y_ref, m_ref,
                nll_ref, chol_ref, z_ref, *, b: int):
    m = m_ref[0, :].reshape(b, 1)
    K, _ = _masked_cov_block(ll_ref[0, :], la_ref[0, 0], ln_ref[0, 0],
                             x_ref[0], m, b)
    L, idx = _chol_loop(K, b)
    ym = y_ref[0, :].reshape(b, 1) * m
    z, diag = _fwd_solve(L, ym, idx, b)
    nll_ref[0, 0] = (0.5 * jnp.sum(z * z) + jnp.sum(jnp.log(diag))
                     + 0.5 * jnp.sum(m) * _LOG_2PI)
    chol_ref[0] = L
    z_ref[0, :] = z[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gp_nll_chol(log_ls, log_amp, log_noise, x, y, mask, *,
                interpret: bool = False):
    """Fused batched NLL; also returns the (chol, z) residuals the
    analytic backward pass reuses.  Shapes as in ``ref.gp_nll_ref``."""
    k, b, d = x.shape
    f32 = jnp.float32
    nll, chol, z = pl.pallas_call(
        functools.partial(_nll_kernel, b=b),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, 1), f32),
            jax.ShapeDtypeStruct((k, b, b), f32),
            jax.ShapeDtypeStruct((k, b), f32),
        ],
        interpret=interpret,
    )(log_ls.astype(f32), log_amp.astype(f32).reshape(k, 1),
      log_noise.astype(f32).reshape(k, 1), x.astype(f32),
      y.astype(f32), mask.astype(f32))
    return nll[:, 0], chol, z


def _nll_bwd_lane(ll, la, ln, xs, ms, L, z, g):
    """Analytic per-lane NLL gradient: dNLL/dθ = tr(S·∂K/∂θ) with
    S = ½(K⁻¹ − αα'), α = L⁻ᵀz — plain jnp, shared by TPU + interpret."""
    b = xs.shape[0]
    ls = jnp.exp(ll)
    amp2 = jnp.exp(2.0 * la)
    alpha = jax.scipy.linalg.solve_triangular(L, z, lower=True, trans=1)
    linv = jax.scipy.linalg.solve_triangular(L, jnp.eye(b), lower=True)
    S = 0.5 * (linv.T @ linv - jnp.outer(alpha, alpha))
    mm = ms[:, None] * ms[None, :]
    smm = S * mm
    diff = xs[:, None, :] - xs[None, :, :]          # (b,b,d)
    sq_k = (diff / ls) ** 2
    r = jnp.sqrt(jnp.maximum(jnp.sum(sq_k, -1), 0.0) + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    e = jnp.exp(-s5r)
    mat = amp2 * (1.0 + s5r + (5.0 / 3.0) * r * r) * e
    # ∂k/∂log_ls_k = amp2·(5/3)(1+√5r)e^{−√5r}·d_k²/ls_k²
    coeff = amp2 * (5.0 / 3.0) * (1.0 + s5r) * e
    g_ll = g * jnp.einsum("ij,ij,ijk->k", smm, coeff, sq_k)
    g_la = g * 2.0 * jnp.sum(smm * mat)
    g_ln = g * 2.0 * jnp.exp(2.0 * ln) * jnp.sum(jnp.diagonal(S) * ms)
    g_y = g * (alpha * ms)                          # dNLL/dy = K⁻¹(y·m)·m
    return g_ll, g_la, g_ln, g_y


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _gp_nll(log_ls, log_amp, log_noise, x, y, mask, interpret):
    nll, _, _ = gp_nll_chol(log_ls, log_amp, log_noise, x, y, mask,
                            interpret=interpret)
    return nll


def _gp_nll_fwd(log_ls, log_amp, log_noise, x, y, mask, interpret):
    nll, chol, z = gp_nll_chol(log_ls, log_amp, log_noise, x, y, mask,
                               interpret=interpret)
    return nll, (log_ls, log_amp, log_noise, x, mask, chol, z)


def _gp_nll_bwd(interpret, res, g):
    log_ls, log_amp, log_noise, x, mask, chol, z = res
    g_ll, g_la, g_ln, g_y = jax.vmap(_nll_bwd_lane)(
        log_ls.astype(jnp.float32), log_amp.astype(jnp.float32),
        log_noise.astype(jnp.float32), x.astype(jnp.float32),
        mask.astype(jnp.float32), chol, z, g.astype(jnp.float32))
    return (g_ll, g_la, g_ln, jnp.zeros_like(x), g_y,
            jnp.zeros_like(mask))


_gp_nll.defvjp(_gp_nll_fwd, _gp_nll_bwd)


def gp_nll(log_ls, log_amp, log_noise, x, y, mask, *,
           interpret: bool = False):
    """Batched masked neg-MLL, Pallas-fused forward + analytic backward.
    Hyperparameter and y cotangents are exact; x/mask cotangents are
    zeros (the fit loop never differentiates them)."""
    return _gp_nll(log_ls, log_amp, log_noise, x, y, mask, interpret)


# ------------------------------------------------------------------- EI
def _ei_kernel(ll_ref, la_ref, x_ref, m_ref, L_ref, a_ref, ymn_ref,
               ystd_ref, cand_ref, best_ref, ei_ref, *, b: int, xi: float):
    ls = jnp.exp(ll_ref[0, :])
    amp2 = jnp.exp(2.0 * la_ref[0, 0])
    m = m_ref[0, :].reshape(b, 1)
    xs = x_ref[0] / ls[None, :]                    # (b,d)
    cq = cand_ref[0] / ls[None, :]                 # (mc,d)
    sq = jnp.maximum(
        jnp.sum(cq * cq, axis=1, keepdims=True)
        - 2.0 * jnp.dot(cq, xs.T, preferred_element_type=jnp.float32)
        + jnp.sum(xs * xs, axis=1, keepdims=True).T, 0.0)
    r = jnp.sqrt(sq + 1e-12)
    s5r = jnp.sqrt(5.0) * r
    kq = amp2 * (1.0 + s5r + (5.0 / 3.0) * r * r) * jnp.exp(-s5r) * m.T
    alpha = a_ref[0, :].reshape(b, 1)
    mu = jnp.dot(kq, alpha, preferred_element_type=jnp.float32)  # (mc,1)
    L = L_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    v, _ = _fwd_solve(L, kq.T, idx, b)             # (b,mc)
    var = jnp.maximum(amp2 - jnp.sum(v * v, axis=0, keepdims=True), 1e-12)
    ystd = ystd_ref[0, 0]
    mu = mu * ystd + ymn_ref[0, 0]
    sd = jnp.sqrt(var).T * ystd                    # (mc,1)
    z = (mu - best_ref[0, 0] - xi) / sd
    ncdf = 0.5 * (1.0 + jax.lax.erf(z / jnp.sqrt(2.0)))
    npdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    ei_ref[0, :] = ((mu - best_ref[0, 0] - xi) * ncdf + sd * npdf)[:, 0]


@functools.partial(jax.jit, static_argnames=("xi", "interpret"))
def gp_ei(log_ls, log_amp, x, mask, chol, alpha, y_mean, y_std,
          cand, best, *, xi: float = 0.01, interpret: bool = False):
    """Fused batched EI over per-lane posteriors; shapes as in
    ``ref.gp_ei_ref`` -> ei (k,m) in raw y units."""
    k, b, d = x.shape
    mc = cand.shape[1]
    f32 = jnp.float32
    col = lambda a: a.astype(f32).reshape(k, 1)
    ei = pl.pallas_call(
        functools.partial(_ei_kernel, b=b, xi=float(xi)),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, mc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, mc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, mc), f32),
        interpret=interpret,
    )(log_ls.astype(f32), col(log_amp), x.astype(f32), mask.astype(f32),
      chol.astype(f32), alpha.astype(f32), col(y_mean), col(y_std),
      cand.astype(f32), col(best))
    return ei
