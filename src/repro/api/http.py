"""HTTP backend for the suggestion service (stdlib-only).

``serve_api`` exposes a ``LocalClient`` as JSON endpoints under
``/v1/experiments/...`` so remote workers on other hosts can run the
suggest/observe loop against one service process (paper §3.5: workers are
thin clients of a central suggestion service).  ``HTTPClient`` is the
matching ``SuggestionClient`` — ``Scheduler`` runs unchanged against
either backend.

Endpoint map (full schemas in API.md):
  POST /v1/experiments                          create / resume
  GET  /v1/experiments/{id}                     status
  POST /v1/experiments/{id}/suggestions         suggest   {count}
  POST /v1/experiments/{id}/observations        observe
  POST /v1/experiments/{id}/trials/{tid}/report report    {step, value}
  POST /v1/experiments/{id}/release             release   {suggestion_id}
  POST /v1/experiments/{id}/requeue             requeue   {suggestion_id}
  POST /v1/experiments/{id}/drain               drain (fleet handover)
  POST /v1/experiments/{id}/stop                stop      {state}
  GET  /v1/experiments/{id}/best                best
  POST /v1/batch                                batched ops (transport plane)
  GET  /v1/healthz                              liveness
  GET  /v1/load                                 shard load (fleet admission)
"""
from __future__ import annotations

import http.client
import json
import random
import socket
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional, Tuple, Union

from repro.api.client import SuggestionClient
from repro.api.local import LocalClient
from repro.api.protocol import (ApiError, BatchRequest, BatchResponse,
                                BestResponse, CreateExperiment,
                                CreateResponse, Decision, DrainRequest,
                                DrainResponse, E_BAD_REQUEST,
                                E_INTERNAL, ObserveRequest, ObserveResponse,
                                PROTOCOL_VERSION, ReleaseRequest,
                                ReleaseResponse, ReportRequest,
                                RequeueRequest, StatusResponse, StopRequest,
                                SuggestBatch, SuggestRequest)
from repro.api.transport import (FLUSH_DEADLINE_S, FLUSH_MAX_OPS,
                                 DecisionGate, OP_OBSERVE, OP_RELEASE,
                                 OP_REPORT, WriteBehind)
from repro.core.store import Store


def _parse_path(path: str):
    """-> (exp_id | None, action | None, trial_id | None); raises ApiError
    on bad paths.  ``trial_id`` is only set for the nested trial-events
    route ``/v1/experiments/{id}/trials/{tid}/report``."""
    parts = [p for p in path.split("?")[0].split("/") if p]
    if parts == ["v1", "healthz"]:
        return None, "healthz", None
    if parts == ["v1", "load"]:
        return None, "load", None
    if parts == ["v1", "batch"]:
        return None, "batch", None
    if not parts or parts[0] != "v1" or len(parts) < 2 \
            or parts[1] != "experiments" or len(parts) > 6:
        raise ApiError(E_BAD_REQUEST, f"no route for {path!r}")
    exp_id = parts[2] if len(parts) > 2 else None
    if len(parts) > 4:
        if len(parts) != 6 or parts[3] != "trials" or parts[5] != "report":
            raise ApiError(E_BAD_REQUEST, f"no route for {path!r}")
        return exp_id, "report", parts[4]
    action = parts[3] if len(parts) > 3 else None
    if action not in (None, "suggestions", "observations", "release",
                      "requeue", "drain", "stop", "best"):
        raise ApiError(E_BAD_REQUEST, f"unknown action {action!r}")
    return exp_id, action, None


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # The response is written as two segments (headers, then body).  With
    # Nagle on, the second small write sits in the kernel until the
    # client's *delayed ACK* (~40 ms) releases it — which was the entire
    # observed cost of the small-RPC hot path (report p50 ≈ 43 ms).
    # TCP_NODELAY ships both segments immediately.
    disable_nagle_algorithm = True
    backend: LocalClient = None           # set by serve_api

    # silence per-request stderr lines
    def log_message(self, fmt, *args):    # noqa: D102
        pass

    def _read_body(self) -> dict:
        raw = self._take_body() or b"{}"
        try:
            return json.loads(raw)
        except json.JSONDecodeError as e:
            raise ApiError(E_BAD_REQUEST, f"invalid JSON body: {e}")

    def _take_body(self) -> bytes:
        """Consume the request body exactly once.  Every request must end
        up drained — an unread body would be parsed as the next request
        line on a keep-alive connection."""
        if getattr(self, "_body", None) is None:
            n = int(self.headers.get("Content-Length") or 0)
            self._body = self.rfile.read(n) if n else b""
        return self._body

    def _send(self, status: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _dispatch(self, method: str) -> None:
        self._body = None
        try:
            exp_id, action, trial_id = _parse_path(self.path)
            self._send(200, self._route(method, exp_id, action, trial_id))
        except ApiError as e:
            self._send(e.http_status, e.to_json())
        except Exception as e:  # noqa: service must answer, not die
            err = ApiError(E_INTERNAL, f"{type(e).__name__}: {e}")
            self._send(err.http_status, err.to_json())
        finally:
            self._take_body()   # drain for keep-alive reuse

    def _route(self, method: str, exp_id: Optional[str],
               action: Optional[str],
               trial_id: Optional[str] = None) -> dict:
        b = self.backend
        if action == "healthz":
            return {"ok": True, "version": PROTOCOL_VERSION}
        if action == "load":
            # shard saturation snapshot — the fleet manager's admission-
            # control probe (FitExecutor backlog + duty cycle)
            return b.load()
        if action == "batch":
            # transport plane: one POST carries an ordered op batch; the
            # backend applies it grouped per experiment (one lock
            # acquisition per group) with exactly-once replay by batch_id
            return b.apply_batch(
                BatchRequest.from_json(self._read_body())).to_json()
        if method == "POST" and exp_id is None and action is None:
            req = CreateExperiment.from_json(self._read_body())
            return b.create_experiment(req).to_json()
        if exp_id is None:
            raise ApiError(E_BAD_REQUEST, "experiment id required")
        if method == "GET" and action is None:
            return b.status(exp_id).to_json()
        if method == "GET" and action == "best":
            return b.best_response(exp_id).to_json()
        if method != "POST":
            raise ApiError(E_BAD_REQUEST, f"{method} not allowed here")
        body = self._read_body()
        body["exp_id"] = exp_id
        if action == "report":
            body["trial_id"] = trial_id
            return b.report(ReportRequest.from_json(body)).to_json()
        if action == "suggestions":
            req = SuggestRequest.from_json(body)
            return b.suggest(req.exp_id, req.count).to_json()
        if action == "observations":
            return b.observe(ObserveRequest.from_json(body)).to_json()
        if action == "release":
            req = ReleaseRequest.from_json(body)
            ok = b.release(req.exp_id, req.suggestion_id)
            return ReleaseResponse(released=ok).to_json()
        if action == "requeue":
            rq = RequeueRequest.from_json(body)
            return {"requeued": b.requeue(rq.exp_id, rq.suggestion_id,
                                          assignment=rq.assignment)}
        if action == "drain":
            req = DrainRequest.from_json(body)
            return b.drain(req.exp_id).to_json()
        if action == "stop":
            req = StopRequest.from_json(body)
            return b.stop(req.exp_id, req.state).to_json()
        raise ApiError(E_BAD_REQUEST, f"no route for {self.path!r}")

    def do_GET(self):   # noqa: N802
        self._dispatch("GET")

    def do_POST(self):  # noqa: N802
        self._dispatch("POST")


class ApiServer:
    """Owns the HTTP listener and the backing ``LocalClient``."""

    def __init__(self, backend: LocalClient, host: str, port: int):
        self.backend = backend
        handler = type("BoundHandler", (_Handler,), {"backend": backend})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ApiServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="suggestion-api", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._httpd.serve_forever()

    def shutdown(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        # drain the suggestion pipeline: prefetch pumps must not keep
        # speculating (or hold optimizer locks) past the listener's death
        self.backend.close()


def serve_api(store: Union[Store, str, LocalClient],
              host: str = "127.0.0.1", port: int = 0) -> ApiServer:
    """Build (but don't start) an API server over a store root, a
    ``Store``, or an existing ``LocalClient``.  ``port=0`` picks a free
    port; read it back from ``server.port``/``server.url``."""
    backend = store if isinstance(store, LocalClient) else LocalClient(store)
    return ApiServer(backend, host, port)


RETRY_BASE_S = 0.05      # first backoff upper bound
RETRY_CAP_S = 2.0        # backoff ceiling
RETRY_ATTEMPTS = 4       # max total attempts for a retryable failure


class HTTPClient(SuggestionClient):
    """Remote-worker side of the wire: a ``SuggestionClient`` that speaks
    the v1 JSON protocol against ``serve_api``.

    Transport: one persistent keep-alive ``http.client.HTTPConnection``
    per thread (the scheduler loop pays one TCP handshake total instead of
    one per request).  A request that fails on a *reused* connection —
    the server closed an idle keep-alive — transparently reconnects and
    retries immediately (the server never saw it).

    Beyond that, transient failures get **bounded exponential backoff
    with full jitter** (base 50 ms doubling to a 2 s cap, ≤4 attempts,
    ``sleep ~ U(0, min(cap, base·2^k))``): a send-phase failure or
    refused connect provably never reached the service, so any verb may
    retry; a *response*-phase failure is ambiguous (the server may have
    committed), so only idempotent verbs retry — a non-idempotent resend
    (suggest) would leak pending budget.  Per-client counters live in
    ``self.stats`` and ride along in ``StatusResponse.transport`` so
    tests assert retry behavior instead of sleeping.

    ``fault_gate`` (chaos harness, ``core.faults.FaultPlan.edge_gate``)
    is consulted before every attempt and raises ``InjectedPartition``
    — a ``ConnectionRefusedError`` — so injected faults exercise these
    exact retry paths.

    ``batch=True`` turns on the write-behind transport plane (API.md
    §Transport batching): observe/release become fire-and-forget
    enqueues, reports ride unless they can cross an ASHA rung
    (:class:`DecisionGate`), and any blocking verb first drains the
    queue.  Batches POST ``/v1/batch`` as idempotent requests — the
    backoff machinery above retries whole batches by ``batch_id`` and
    the server's dedupe window makes redelivery exactly-once."""

    def __init__(self, base_url: str, timeout: float = 30.0,
                 retry_attempts: int = RETRY_ATTEMPTS,
                 retry_base: float = RETRY_BASE_S,
                 retry_cap: float = RETRY_CAP_S,
                 retry_seed: Optional[int] = None,
                 fault_gate: Optional[Callable[[], None]] = None,
                 batch: bool = False,
                 batch_max: int = FLUSH_MAX_OPS,
                 batch_deadline: float = FLUSH_DEADLINE_S):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        u = urllib.parse.urlsplit(self.base_url)
        if u.scheme not in ("http", "https"):
            raise ValueError(f"unsupported scheme in {base_url!r}")
        self._conn_cls = (http.client.HTTPSConnection if u.scheme == "https"
                          else http.client.HTTPConnection)
        self._host = u.hostname or "127.0.0.1"
        self._port = u.port or (443 if u.scheme == "https" else 80)
        self._prefix = u.path.rstrip("/")
        self._local = threading.local()
        self.retry_attempts = max(1, retry_attempts)
        self.retry_base = retry_base
        self.retry_cap = retry_cap
        self.fault_gate = fault_gate
        self._rng = random.Random(retry_seed)
        self._stats_lock = threading.Lock()
        self.stats = {"retries": 0,      # re-sent requests (all causes)
                      "backoffs": 0,     # retries that slept first
                      "backoff_ms": 0.0,  # total time slept
                      "refused": 0,      # connection-refused failures seen
                      "gave_up": 0}      # requests failed after all attempts
        self._wb: Optional[WriteBehind] = None
        self._gate: Optional[DecisionGate] = None
        if batch:
            self._gate = DecisionGate()
            self._wb = WriteBehind(self._send_batch, max_ops=batch_max,
                                   deadline=batch_deadline,
                                   on_result=self._on_batch_result,
                                   name=f"wb-{self._host}:{self._port}")

    def _backoff(self, attempt: int) -> None:
        """Full-jitter sleep before retry ``attempt`` (0-based)."""
        delay = self._rng.uniform(
            0.0, min(self.retry_cap, self.retry_base * (2 ** attempt)))
        with self._stats_lock:
            self.stats["retries"] += 1
            self.stats["backoffs"] += 1
            self.stats["backoff_ms"] += delay * 1e3
        if delay > 0.0:
            time.sleep(delay)

    def _count(self, key: str) -> None:
        with self._stats_lock:
            self.stats[key] += 1

    # ------------------------------------------------------------ transport
    def _conn(self) -> Tuple[http.client.HTTPConnection, bool]:
        """-> (connection, fresh); fresh=True when newly established."""
        c = getattr(self._local, "conn", None)
        if c is not None:
            return c, False
        c = self._conn_cls(self._host, self._port, timeout=self.timeout)
        self._local.conn = c
        return c, True

    def _drop_conn(self) -> None:
        c = getattr(self._local, "conn", None)
        self._local.conn = None
        if c is not None:
            try:
                c.close()
            except OSError:
                pass

    def close(self) -> None:
        """Flush any write-behind queue, then close this thread's
        persistent connection (idempotent)."""
        if self._wb is not None:
            self._wb.close()
        self._drop_conn()

    def _call(self, method: str, path: str, payload: Optional[dict] = None,
              idempotent: bool = True) -> dict:
        body = json.dumps(payload).encode() if payload is not None else None
        headers = {"Content-Type": "application/json"}
        url = self._prefix + path
        attempt = 0                     # backoff retries consumed
        while True:
            conn, fresh = self._conn()
            try:
                if self.fault_gate is not None:
                    self.fault_gate()
                conn.request(method, url, body=body, headers=headers)
                if fresh and conn.sock is not None:
                    # belt-and-braces to the server-side Nagle disable:
                    # never let a small client segment wait on delayed ACK
                    try:
                        conn.sock.setsockopt(socket.IPPROTO_TCP,
                                             socket.TCP_NODELAY, 1)
                    except OSError:
                        pass
            except (http.client.HTTPException, ConnectionError, OSError) as e:
                # send-phase failure: the socket rejected the write, so
                # the server never processed the request — safe to
                # reconnect and retry even for non-idempotent verbs
                self._drop_conn()
                refused = isinstance(e, ConnectionRefusedError)
                if refused:
                    self._count("refused")
                if not fresh:
                    # stale keep-alive: free immediate retry, next is fresh
                    self._count("retries")
                    continue
                if attempt + 1 >= self.retry_attempts:
                    self._count("gave_up")
                    raise ApiError(E_INTERNAL, f"service unreachable: {e}")
                self._backoff(attempt)
                attempt += 1
                continue
            try:
                resp = conn.getresponse()
                raw = resp.read()       # drain fully so the conn is reusable
                status = resp.status
                if resp.will_close:
                    self._drop_conn()
            except (http.client.HTTPException, ConnectionError, OSError) as e:
                self._drop_conn()
                if not idempotent:
                    # response-phase failure is ambiguous — the server may
                    # have committed the request.  Non-idempotent verbs
                    # (suggest) must not auto-retry here: a blind resend
                    # would leak pending budget — surface the error and
                    # let the caller decide
                    raise ApiError(E_INTERNAL, f"service unreachable: {e}")
                if not fresh:
                    self._count("retries")
                    continue            # stale keep-alive: retry once, fresh
                if attempt + 1 >= self.retry_attempts:
                    self._count("gave_up")
                    raise ApiError(E_INTERNAL, f"service unreachable: {e}")
                self._backoff(attempt)
                attempt += 1
                continue
            if status >= 400:
                try:
                    raise ApiError.from_json(json.loads(raw or b"{}"))
                except json.JSONDecodeError:
                    raise ApiError(E_INTERNAL,
                                   f"HTTP {status} from {self.base_url}{path}")
            return json.loads(raw or b"{}")

    # ------------------------------------------------------------- batching
    def _send_batch(self, lane, req: BatchRequest) -> BatchResponse:
        """WriteBehind transport: batches are idempotent by ``batch_id``
        (server dedupe window), so the full retry machinery — including
        ambiguous response-phase failures — may resend them whole."""
        return BatchResponse.from_json(
            self._call("POST", "/v1/batch", req.to_json()))

    def apply_batch(self, req: BatchRequest) -> BatchResponse:
        """Ship one pre-built batch (the ``FleetClient`` per-shard path
        uses this directly on HTTP shard transports)."""
        return self._send_batch(None, req)

    def _on_batch_result(self, lane, op, result, err) -> bool:
        if err is None and op.kind == OP_REPORT and self._gate is not None:
            # feed the decision cache so future reports from this trial
            # know their next rung (and stash any stop/pause for the
            # trial's next report)
            p = op.payload
            self._gate.note((p.get("exp_id"),
                             p.get("suggestion_id") or p.get("trial_id")),
                            Decision.from_json(result.result))
        return False    # default accounting for failures

    def flush(self) -> None:
        """Drain the write-behind queue (no-op when batching is off)."""
        if self._wb is not None:
            self._wb.flush()

    # -------------------------------------------------------------- protocol
    def create_experiment(self, req: CreateExperiment) -> CreateResponse:
        self.flush()
        return CreateResponse.from_json(
            self._call("POST", "/v1/experiments", req.to_json()))

    def suggest(self, exp_id: str, count: int = 1) -> SuggestBatch:
        self.flush()
        return SuggestBatch.from_json(
            self._call("POST", f"/v1/experiments/{exp_id}/suggestions",
                       {"count": count}, idempotent=False))

    def observe(self, req: ObserveRequest) -> ObserveResponse:
        if self._wb is not None:
            # fire-and-forget: the synthetic ack stands in for the wire
            # response; duplicates are resolved server-side on flush
            self._wb.enqueue(OP_OBSERVE, req.to_json())
            return ObserveResponse(accepted=True, duplicate=False,
                                   observations=-1)
        return ObserveResponse.from_json(
            self._call("POST",
                       f"/v1/experiments/{req.exp_id}/observations",
                       req.to_json()))

    def report(self, req: ReportRequest) -> Decision:
        # idempotent in the ways that matter: a retried report appends a
        # duplicate metric line (harmless — rung recording dedupes by
        # trial), so the keep-alive retry path stays enabled.  Reuses the
        # persistent connection: the trial-events hot path pays no TCP
        # handshake per report.
        if self._wb is not None:
            stashed = self._gate.take_stashed(req)
            if stashed is not None:
                return stashed      # stop/pause that arrived on a batch
            if not self._gate.blocking(req):
                self._wb.enqueue(OP_REPORT, req.to_json())
                return self._gate.ride_decision(req)
            self._wb.flush()        # ordering: queued ops land first
        d = Decision.from_json(
            self._call("POST",
                       f"/v1/experiments/{req.exp_id}/trials"
                       f"/{req.trial_id or req.suggestion_id}/report",
                       req.to_json()))
        if self._gate is not None:
            self._gate.note(self._gate.key(req), d)
            self._gate.take_stashed(req)    # delivered directly: unstash
        return d

    def release(self, exp_id: str, suggestion_id: str) -> bool:
        if self._wb is not None:
            self._wb.enqueue(OP_RELEASE,
                             {"exp_id": exp_id,
                              "suggestion_id": suggestion_id})
            return True
        resp = self._call("POST", f"/v1/experiments/{exp_id}/release",
                          {"suggestion_id": suggestion_id})
        return ReleaseResponse.from_json(resp).released

    def requeue(self, exp_id: str, suggestion_id: str,
                assignment: Optional[dict] = None) -> bool:
        self.flush()
        resp = self._call("POST", f"/v1/experiments/{exp_id}/requeue",
                          {"suggestion_id": suggestion_id,
                           "assignment": assignment})
        return bool(resp.get("requeued", False))

    def drain(self, exp_id: str) -> DrainResponse:
        """Quiesce the experiment on the serving shard ahead of a
        handover (``POST .../drain``) — fleet rebalance control plane."""
        self.flush()
        return DrainResponse.from_json(
            self._call("POST", f"/v1/experiments/{exp_id}/drain", {}))

    def load(self) -> dict:
        """Shard saturation snapshot (``GET /v1/load``) — consumed by the
        fleet manager's admission/probe loop."""
        return self._call("GET", "/v1/load")

    def status(self, exp_id: str) -> StatusResponse:
        self.flush()
        resp = StatusResponse.from_json(
            self._call("GET", f"/v1/experiments/{exp_id}"))
        # additive client-side view: this client's transport retry
        # counters ride along so harnesses can assert retry behavior
        with self._stats_lock:
            resp.transport = dict(self.stats)
        if self._wb is not None:
            resp.transport["batch"] = dict(self._wb.stats)
            resp.transport["batch"]["depth"] = self._wb.depth()
        return resp

    def stop(self, exp_id: str, state: str = "stopped") -> StatusResponse:
        self.flush()
        return StatusResponse.from_json(
            self._call("POST", f"/v1/experiments/{exp_id}/stop",
                       {"state": state}))

    def best_response(self, exp_id: str) -> BestResponse:
        self.flush()
        return BestResponse.from_json(
            self._call("GET", f"/v1/experiments/{exp_id}/best"))

    def healthz(self) -> dict:
        return self._call("GET", "/v1/healthz")
