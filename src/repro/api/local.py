"""In-process suggestion-service backend.

``LocalClient`` owns what the scheduler used to reach into directly: the
optimizer (via ``make_optimizer``) and the system-of-record ``Store``.
All state transitions are lock-guarded, and every handed-out assignment is
tracked as a *pending suggestion*, so concurrent ``suggest`` calls from
parallel workers never receive duplicate assignments and never
oversubscribe the observation budget.

Suggestion pipeline (ISSUE 4): suggestion latency is decoupled from model
cost.  Per experiment, two locks split the work:

* ``state.lock`` — cheap bookkeeping (pending set, counters, queue pops).
  ``suggest`` normally completes under this lock alone: it pops a
  pre-computed suggestion from the prefetch queue in ~µs.
* ``state.opt_lock`` — serializes *all* optimizer compute (ask / tell /
  forget / restore).  Held by the background :class:`SuggestionPump`
  (which keeps the queue warm, folds deferred observations, refits
  hyperparameters, and prewarms XLA shape buckets) and by the coalesced
  miss path, where N concurrent queue misses are served by ONE batched
  ``ask(n)`` instead of N serialized fits.

``observe``/``release`` never touch the optimizer inline: they enqueue a
deferred tell/forget op (``state.ops``) and wake the pump; with the pump
disabled (``prefetch=0``) the op is drained synchronously, preserving the
fully-synchronous pre-pipeline semantics.  Lock order is always
``opt_lock`` before ``state.lock``; ``state.ops`` is popped only under
``opt_lock`` (see ``pipeline.drain_ops``), which makes resume's
"drain, then replay the log tail" sequence race-free.

This same object is also the backend behind ``serve_api`` — the HTTP layer
is a thin JSON shim over a ``LocalClient``.
"""
from __future__ import annotations

import json
import threading
import time
import uuid
from typing import Dict, List, Optional, Set, Union

from repro.api import pipeline
from repro.api.client import SuggestionClient
from repro.api.pipeline import (MissSlot, PrefetchItem, SuggestionPump,
                                drain_ops, pop_prefetched, retire_queue,
                                serve_misses)
from repro.api.protocol import (ApiError, BatchOpResult, BatchRequest,
                                BatchResponse, BestResponse,
                                CreateExperiment, CreateResponse,
                                DECISION_STOP, Decision, DrainResponse,
                                E_FENCED, E_INTERNAL, E_UNKNOWN_EXPERIMENT,
                                E_WRONG_SHARD, EPOCH_ZERO, ObserveRequest,
                                ObserveResponse, ReleaseRequest,
                                ReleaseResponse, ReportRequest,
                                RequeueRequest, StatusResponse, SuggestBatch,
                                Suggestion, epoch_tuple)
from repro.core.experiment import ExperimentConfig
from repro.core.space import strip_internal
from repro.core.store import FencedError, Store
from repro.core.suggest.base import (Observation, Optimizer, StoppingPolicy,
                                     make_optimizer, make_stopping_policy)


class _ExperimentState:
    """Live service-side state for one experiment (pending set, prefetch
    queue, and deferred-op list are in-memory only; a service restart
    reclaims all pending budget and speculative suggestions — early-
    stopping rung state, by contrast, IS durable: snapshot in the
    experiment record + replay of the per-trial metric logs)."""

    def __init__(self, cfg: ExperimentConfig, optimizer: Optimizer,
                 stopper: Optional[StoppingPolicy] = None):
        self.cfg = cfg
        self.optimizer = optimizer
        self.stopper = stopper
        self.lock = threading.RLock()        # bookkeeping (fast paths)
        self.opt_lock = threading.RLock()    # optimizer compute (slow paths)
        self.pending: Dict[str, Suggestion] = {}
        self.orphaned: List[Suggestion] = []  # requeued pending (dead worker)
        self.sparse_ids: Set[str] = set()     # served off the sparse posterior
        self.closed: Set[str] = set()
        self.observed = 0
        self.failures = 0
        self.stopped = False
        self.best: Optional[Observation] = None
        self.metric_seq = 0          # high-water mark of the metric stream
        # --- pipeline state (see repro.api.pipeline) ---
        self.queue: List[PrefetchItem] = []      # warm speculative asks
        self.ops: List[tuple] = []               # deferred tell/forget
        self.miss_slots: List[MissSlot] = []     # coalescing parked misses
        self.pump: Optional[SuggestionPump] = None
        self.staleness = max(1, cfg.staleness)
        self.stats = {"hits": 0, "misses": 0, "coalesced": 0,
                      "invalidated": 0, "prefilled": 0, "prewarmed": 0,
                      "batched_prefilled": 0,
                      "sparse_prefilled": 0, "sparse_served": 0,
                      "requeued": 0, "requeue_served": 0,
                      # sparse-vs-exact quality on finished trials (the
                      # SPARSE_MAX tuning signal, ROADMAP sparse quality)
                      "sparse_obs": 0, "sparse_regret": 0.0,
                      "exact_obs": 0, "exact_regret": 0.0}
        # ownership fence (API.md §Fleet / Fencing): the epoch this
        # incarnation adopted the experiment at; ``fenced`` flips once a
        # newer incarnation's claim is detected and is terminal for this
        # state object (a re-create re-claims and replaces it)
        self.epoch = EPOCH_ZERO
        self.fenced = False
        self.last_mirror = 0.0       # status.json mirror throttle
        self.appends = 0             # observes between log append + account
        self.append_cv = threading.Condition(self.lock)
        self._seq = 0
        self._sid_nonce = uuid.uuid4().hex[:6]
        self._snap_version = -1      # stopper.version last persisted

    def next_suggestion_id(self) -> str:
        self._seq += 1
        # the nonce makes ids unique across state *incarnations*: after a
        # shard dies, the adopting shard's counter restarts, and a bare
        # sequence number would re-mint ids that are already in the
        # observation log (breaking closed-set dedupe for stale workers)
        return f"s{self._sid_nonce}-{self._seq:05d}"

    def pump_depth(self) -> int:
        """Resolved prefetch depth: an explicit ``cfg.prefetch`` wins;
        ``None`` auto-enables the pump only for optimizers whose ``ask``
        is expensive (model-based — GP), sized to cover one full
        slot-fill burst plus refill headroom."""
        if self.cfg.prefetch is not None:
            return max(0, int(self.cfg.prefetch))
        if getattr(self.optimizer, "expensive_ask", False):
            return max(2, min(2 * int(self.cfg.parallel), 16))
        return 0


def _public_best(best) -> Optional[Dict]:
    """Serialize a best observation for user-facing readouts, stripping
    internal ``__``-prefixed echo keys (constant-liar tokens, particle
    ids) from the assignment."""
    if best is None:
        return None
    d = best.to_json()
    if isinstance(d.get("assignment"), dict):
        d["assignment"] = strip_internal(d["assignment"])
    return d


DRAINED_TOMBSTONES = 1024    # max remembered handed-over experiments
BATCH_DEDUPE_WINDOW = 512    # applied batches remembered for replay


class LocalClient(SuggestionClient):
    def __init__(self, store: Union[Store, str]):
        self.store = store if isinstance(store, Store) else Store(store)
        self._exps: Dict[str, _ExperimentState] = {}
        self._lock = threading.Lock()
        # owner token: unique per service incarnation — the second half of
        # the fence record (epoch orders ownership across grants; the
        # token disambiguates incarnations within one epoch)
        self.incarnation = f"svc-{uuid.uuid4().hex[:8]}"
        # experiments drained off this shard (rebalance handover): answer
        # wrong_shard — not unknown_experiment — so routed clients refresh
        # the map instead of re-adopting here
        self._drained: Dict[str, float] = {}
        # exactly-once batch replay (API.md §Transport batching):
        # batch_id -> ("inflight", Event) | ("done", BatchResponse).
        # Bounded window: a transport retry redelivers promptly, so only
        # the recent past needs remembering.
        self._batch_lock = threading.Lock()
        self._batches: Dict[str, tuple] = {}

    # -------------------------------------------------------------- fencing
    def _tombstone(self, exp_id: str) -> None:
        # holding self._lock
        self._drained[exp_id] = time.time()
        while len(self._drained) > DRAINED_TOMBSTONES:
            self._drained.pop(next(iter(self._drained)))

    def _claim_fence(self, exp_id: str, epoch) -> tuple:
        """Adopt the experiment's fence record.  An explicit ``epoch`` is
        a manager grant (claim exactly there — stale grants from a
        deposed manager raise ``fenced``); without one, an *existing*
        record is re-claimed at its current epoch (owner swap: last
        adopter within an epoch wins), and an absent record is left
        absent — standalone services never enter the fencing regime."""
        try:
            if epoch is not None:
                return self.store.claim_fence(exp_id, epoch_tuple(epoch),
                                              self.incarnation)
            cur, owner = self.store.read_fence(exp_id)
            if cur == EPOCH_ZERO and not owner:
                return EPOCH_ZERO
            return self.store.claim_fence(exp_id, cur, self.incarnation)
        except FencedError as e:
            raise ApiError(E_FENCED, str(e))

    def _check_fence(self, exp_id: str, state: _ExperimentState) -> None:
        """Write guard: every durable write re-validates ownership (one
        cached os.stat).  On a lost fence the incarnation stands down —
        pump stopped, parked misses unblocked, all further calls
        answered ``fenced`` — and the write is rejected *before* it
        reaches the log."""
        if state.fenced:
            raise ApiError(E_FENCED,
                           f"{exp_id}: this incarnation was fenced")
        try:
            self.store.check_fence(exp_id, state.epoch, self.incarnation)
        except FencedError as e:
            self._stand_down(state)
            raise ApiError(E_FENCED, str(e))

    def _stand_down(self, state: _ExperimentState) -> None:
        with state.lock:
            if state.fenced:
                return
            state.fenced = True
            pump = state.pump
            slots, state.miss_slots = state.miss_slots, []
            for sl in slots:
                sl.done = True
                sl.event.set()
        if pump is not None:
            pump.stop(join=False)   # no join: may be called from any path

    # ------------------------------------------------------------ lifecycle
    def create_experiment(self, req: CreateExperiment) -> CreateResponse:
        exp_id = req.exp_id
        if req.config:
            cfg = ExperimentConfig.from_json(req.config)
        else:
            # config-less resume (fleet failover): a new owner shard
            # adopts an experiment it has never seen straight out of the
            # shared system-of-record store
            with self._lock:
                live = self._exps.get(exp_id) if exp_id else None
            if live is not None:
                cfg = live.cfg
            else:
                try:
                    cfg = self.store.load_config(exp_id)
                except FileNotFoundError:
                    raise ApiError(E_UNKNOWN_EXPERIMENT,
                                   f"no experiment {exp_id!r} to adopt")
        with self._lock:
            on_disk = (exp_id is not None
                       and (self.store.exp_dir(exp_id) / "config.json")
                       .exists())
            state = self._exps.get(exp_id) if exp_id else None
            fresh = state is None
            if fresh:
                if exp_id is None:
                    from repro.core.experiment import new_experiment_id
                    exp_id = new_experiment_id()
                if not on_disk:
                    self.store.create_experiment(exp_id, cfg)
            # (re-)adopting clears the handover tombstone: this shard is
            # being told to serve the experiment again
            if exp_id is not None:
                self._drained.pop(exp_id, None)
            if fresh:
                optimizer = make_optimizer(cfg.optimizer, cfg.space,
                                           seed=cfg.seed,
                                           **cfg.optimizer_options)
                stopper = (make_stopping_policy(cfg.early_stop, goal=cfg.goal)
                           if cfg.early_stop else None)
                state = _ExperimentState(cfg, optimizer, stopper)
                # grab both locks BEFORE publishing (canonical order: opt
                # before state) so no concurrent suggest sees observed=0
                # pre-replay
                state.opt_lock.acquire()
                state.lock.acquire()
                self._exps[exp_id] = state
        if not fresh:
            # live re-create/resume: quiesce the pump first, then take the
            # locks in canonical order
            with state.lock:
                pump = state.pump
            if pump is not None:
                pump.stop(join=True)
            state.opt_lock.acquire()
            state.lock.acquire()
        try:
            # claim ownership BEFORE any durable write below: a zombie
            # acting on a deposed manager's grant must fail the whole
            # create, not half-adopt
            try:
                state.epoch = self._claim_fence(exp_id, req.epoch)
            except ApiError:
                if fresh:
                    with self._lock:
                        self._exps.pop(exp_id, None)
                raise
            state.fenced = False
            resumed = on_disk or state.observed > 0
            state.cfg = cfg          # resume may raise the budget
            state.stopped = False    # re-creating declares intent to run
            state.staleness = max(1, cfg.staleness)
            if resumed:
                # keep the stored config in sync with the resumed one
                (self.store.exp_dir(exp_id) / "config.json").write_text(
                    json.dumps(cfg.to_json(), indent=1))
            # quiesce in-flight observes (append done, accounting not yet)
            # so the log, the deferred ops, and the counters agree, then
            # fold the deferred observations BEFORE the replay — the
            # log-tail arithmetic in Optimizer.restore stays exact
            deadline = time.monotonic() + 5.0
            while state.appends and time.monotonic() < deadline:
                state.append_cv.wait(0.1)
            drain_ops(state)
            records = self.store.load_observation_records(exp_id)
            prior = [Observation.from_json(r) for r in records]
            # restore() is idempotent: only the log tail beyond what the
            # optimizer has already absorbed is replayed
            state.optimizer.restore(
                {"history": [o.to_json() for o in prior]})
            # rebuild the duplicate-observe dedupe set from the log: an
            # adopting incarnation must reject a straggler's re-observe
            # of a suggestion the previous owner already logged
            state.closed.update(r["suggestion_id"] for r in records
                                if r.get("suggestion_id"))
            state.observed = len(prior)
            state.failures = sum(1 for o in prior if o.failed)
            ok = [o for o in prior if not o.failed and o.value is not None]
            state.best = max(ok, key=lambda o: o.value) if ok else None
            self._restore_rungs(exp_id, state, cfg)
        finally:
            state.lock.release()
            state.opt_lock.release()
        self._ensure_pump(exp_id, state)
        return CreateResponse(exp_id=exp_id, resumed=resumed,
                              observations=state.observed)

    def _restore_rungs(self, exp_id: str, state: _ExperimentState,
                       cfg: ExperimentConfig) -> None:
        """Resume trial-events state exactly like the observation log:
        load the rung snapshot from the experiment record, replay the
        metric-log tail beyond its ``seq`` high-water mark (crash between
        a metric append and the snapshot write), and advance ``metric_seq``
        past everything on disk so post-restart reports never reuse seq
        numbers — even for experiments with no stopping policy.
        Idempotent — a live state's absorbed stream is never replayed
        twice."""
        if cfg.early_stop and state.stopper is None:
            state.stopper = make_stopping_policy(cfg.early_stop,
                                                 goal=cfg.goal)
        if state.stopper is not None and state.metric_seq == 0:
            snap = self.store.get_status(exp_id).get("rungs")
            if snap:
                state.stopper.restore(snap)
                state.metric_seq = int(snap.get("seq", 0))
                state._snap_version = state.stopper.version
        records = self.store.load_metrics(exp_id)
        tail = [r for r in records if r.get("seq", 0) > state.metric_seq]
        if state.stopper is not None:
            for r in tail:
                state.stopper.report(
                    r.get("trial_key") or r.get("trial_id", ""),
                    int(r["step"]), float(r["value"]))
        if records:
            state.metric_seq = max(
                state.metric_seq,
                max(int(r.get("seq", 0)) for r in records))
        if tail:
            self._snapshot_rungs(exp_id, state)

    def _snapshot_rungs(self, exp_id: str, state: _ExperimentState) -> None:
        """Persist the rung table into the experiment record (status.json)
        whenever it actually changed — reports between rungs don't touch
        policy state and stay off this path."""
        if state.stopper is None or state.stopper.version == state._snap_version:
            return
        snap = dict(state.stopper.state(), seq=state.metric_seq)
        state._snap_version = state.stopper.version
        self.store.update_status(exp_id, rungs=snap)

    def _state(self, exp_id: str) -> _ExperimentState:
        with self._lock:
            state = self._exps.get(exp_id)
            drained = state is None and exp_id in self._drained
        if state is None:
            if drained:
                raise ApiError(E_WRONG_SHARD,
                               f"experiment {exp_id!r} was handed over "
                               f"(drained from this shard)")
            raise ApiError(E_UNKNOWN_EXPERIMENT,
                           f"no live experiment {exp_id!r}")
        return state

    # ------------------------------------------------------------- pipeline
    def _mint(self, state: _ExperimentState, assignment,
              sparse: bool = False) -> Suggestion:
        """Turn an assignment into a tracked pending suggestion.  MUST be
        called with ``state.lock`` held.  ``sparse`` marks suggestions
        served off the approximate posterior so their eventual outcome
        feeds the sparse-vs-exact quality counters."""
        s = Suggestion(state.next_suggestion_id(), assignment)
        state.pending[s.suggestion_id] = s
        if sparse:
            state.sparse_ids.add(s.suggestion_id)
        return s

    def _ensure_pump(self, exp_id: str, state: _ExperimentState) -> None:
        """Start (or restart, e.g. after ``close``/resume) the prefetch
        pump when the config calls for one and the experiment can still
        make progress."""
        depth = state.pump_depth()
        with state.lock:
            if (depth <= 0 or state.stopped
                    or state.observed >= state.cfg.budget):
                return
            if state.pump is not None and state.pump.alive:
                return
            state.pump = SuggestionPump(
                state, exp_id, depth,
                lambda a: self._mint(state, a)).start()

    def _drain_sync(self, state: _ExperimentState) -> None:
        """Apply deferred optimizer ops inline — the no-pump path keeps
        the pre-pipeline synchronous semantics (tells/forgets visible the
        moment observe/release returns)."""
        with state.opt_lock:
            drain_ops(state)

    def _suggest_miss(self, state: _ExperimentState,
                      need: int) -> List[Suggestion]:
        """Queue-dry fallback: park a miss slot and race for the optimizer
        lock; whoever wins serves every parked slot with one batched
        ``ask`` (cross-scheduler coalescing).  Losers just wait — their
        suggestions are computed by the winner (or the pump)."""
        slot = MissSlot(need)
        with state.lock:
            if state.stopped:
                return []
            state.miss_slots.append(slot)
        while not slot.done:
            if state.opt_lock.acquire(timeout=0.02):
                try:
                    if not slot.done:
                        serve_misses(state, lambda a: self._mint(state, a))
                finally:
                    state.opt_lock.release()
            else:
                slot.event.wait(0.02)
        return slot.result

    # ------------------------------------------------------ suggest/observe
    def suggest(self, exp_id: str, count: int = 1) -> SuggestBatch:
        state = self._state(exp_id)
        if state.fenced:
            # cheap flag check only — serving from a not-yet-detected
            # zombie is harmless (its observes are fenced at the log),
            # so the µs hot path pays no stat() here
            raise ApiError(E_FENCED,
                           f"{exp_id}: this incarnation was fenced")
        self._ensure_pump(exp_id, state)
        with state.lock:
            if state.stopped:
                return SuggestBatch([], remaining=0)
            # requeued (orphaned) suggestions are served first: they are
            # already pending — same id, same constant-liar lie — so they
            # consume no budget headroom and are handed out exactly once
            batch: List[Suggestion] = []
            while state.orphaned and len(batch) < int(count):
                s = state.orphaned.pop(0)
                if (s.suggestion_id in state.closed
                        or s.suggestion_id not in state.pending):
                    continue    # observed/released while parked
                batch.append(s)
                state.stats["requeue_served"] += 1
            headroom = (state.cfg.budget - state.observed
                        - len(state.pending))
            n = max(0, min(int(count) - len(batch), headroom))
            fresh, stale = pop_prefetched(state, n)
            batch.extend(self._mint(state, it.assignment, sparse=it.sparse)
                         for it in fresh)
            need = n - len(fresh)
            if stale:
                state.ops.extend(("forget", a) for a in stale)
            pump = state.pump
            refill = len(state.queue) < state.pump_depth()
        if pump is not None and pump.alive:
            if refill or stale or need:
                pump.wake()
        elif stale:
            self._drain_sync(state)
        if need > 0:
            batch.extend(self._suggest_miss(state, need))
        with state.lock:
            remaining = (state.cfg.budget - state.observed
                         - len(state.pending))
        return SuggestBatch(batch, remaining=max(0, remaining))

    def observe(self, req: ObserveRequest) -> ObserveResponse:
        state = self._state(req.exp_id)
        # ownership guard BEFORE any bookkeeping: a fenced incarnation's
        # observation must neither close the suggestion nor reach the log
        self._check_fence(req.exp_id, state)
        obs = Observation(req.assignment, req.value, req.stddev,
                          req.failed, dict(req.metadata))
        with state.lock:
            if req.suggestion_id in state.closed:
                return ObserveResponse(accepted=False, duplicate=True,
                                       observations=state.observed)
            if state.stopped:
                # stopped/deleted experiments take no more observations
                # (a straggler must not flip 'deleted' back to 'complete')
                return ObserveResponse(accepted=False, duplicate=False,
                                       observations=state.observed)
            state.closed.add(req.suggestion_id)
            # the model fold is deferred: the pump (or the next optimizer-
            # lock holder) absorbs it off this hot path.  Enqueued BEFORE
            # the log append: a concurrent live resume drains this op
            # (under opt_lock) before replaying the log, so whether or not
            # its load sees the append below, the optimizer absorbs this
            # observation exactly once (restore only replays the tail
            # beyond len(history)).
            state.ops.append(("tell", obs))
            state.appends += 1
        # system-of-record append OUTSIDE the experiment lock (the store
        # serializes its own handles): holding the lock across file I/O
        # would make every concurrent queue pop wait on a flush.  The
        # closed-set insert above already de-duplicated; the suggestion
        # stays *pending* until the same lock section that increments
        # ``observed``, so budget headroom never transiently inflates.
        # ``appends`` marks the append-to-accounting window so a live
        # resume (create_experiment) can quiesce in-flight observes
        # before deriving counters from the log.
        try:
            self.store.append_observation(req.exp_id, obs, req.trial_id,
                                          suggestion_id=req.suggestion_id)
        except BaseException:
            with state.lock:
                state.appends -= 1
                state.append_cv.notify_all()
            raise
        with state.lock:
            # tolerate untracked ids (service restart lost the pending set)
            state.pending.pop(req.suggestion_id, None)
            state.observed += 1
            state.appends -= 1
            state.append_cv.notify_all()
            if req.failed:
                state.failures += 1
            # sparse-vs-exact quality: instantaneous regret of this
            # finished trial against the best KNOWN BEFORE it, bucketed
            # by which posterior served its suggestion — the SPARSE_MAX
            # tuning signal (ROADMAP: sparse-posterior quality)
            was_sparse = req.suggestion_id in state.sparse_ids
            state.sparse_ids.discard(req.suggestion_id)
            if not obs.failed and obs.value is not None:
                regret = (max(0.0, state.best.value - obs.value)
                          if state.best is not None else 0.0)
                bucket = "sparse" if was_sparse else "exact"
                state.stats[bucket + "_obs"] += 1
                state.stats[bucket + "_regret"] += regret
            if (not obs.failed and obs.value is not None
                    and (state.best is None
                         or obs.value > state.best.value)):
                state.best = obs
            fields = dict(observations=state.observed,
                          failures=state.failures,
                          best=_public_best(state.best))
            complete = state.observed >= state.cfg.budget
            observed = state.observed
            pump = state.pump
        if complete:
            fields["state"] = "complete"
            self.store.update_status(req.exp_id, **fields)
        else:
            self._mirror_status(req.exp_id, state, fields)
        # the trial is terminal: its metric stream will never grow again —
        # evict its file handle from the store LRU so a fleet-scale churn
        # of short trials can't pin thousands of open files
        self._evict_trial_handles(req.exp_id, req.suggestion_id,
                                  req.trial_id)
        if pump is not None and pump.alive:
            pump.wake()     # fold + staleness sweep + refill
        else:
            self._drain_sync(state)
        return ObserveResponse(accepted=True, duplicate=False,
                               observations=observed)

    def _evict_trial_handles(self, exp_id: str, *trial_keys: str) -> None:
        """Close the cached append handles of a terminal trial's metric
        stream (keyed by suggestion_id or trial_id — evict both)."""
        for key in trial_keys:
            if key:
                self.store.release_handle(self.store.metric_path(exp_id,
                                                                 key))

    def _mirror_status(self, exp_id: str, state: _ExperimentState,
                       fields: Dict) -> None:
        """Throttled status.json mirror: the in-memory state (and the
        observation log) are authoritative; the mirror exists for cold
        reads and need not be written per observation under contention.
        Terminal transitions bypass this and always write."""
        now = time.monotonic()
        with state.lock:
            if now - state.last_mirror < 0.05:
                return
            state.last_mirror = now
        self.store.update_status(exp_id, **fields)

    def report(self, req: ReportRequest) -> Decision:
        """Trial-events hot path: append the progress point to the trial's
        metric stream, run it through the experiment's (shared) stopping
        policy, and answer continue/stop/pause.  Single-writer under the
        experiment lock — N schedulers prune against ONE rung table."""
        state = self._state(req.exp_id)
        self._check_fence(req.exp_id, state)   # report appends durably
        with state.lock:
            return self._report_locked(req.exp_id, state, req)

    def _report_locked(self, exp_id: str, state: _ExperimentState,
                       req: ReportRequest) -> Decision:
        """Body of :meth:`report` (fence already checked, ``state.lock``
        held) — shared with the batched apply path, where one lock
        acquisition covers a whole per-experiment op group."""
        if state.stopped:
            # deleted/stopped experiments wind their trials down via
            # the next report, even without a worker-side stop flag
            return Decision(DECISION_STOP, next_rung=None,
                            seq=state.metric_seq)
        # suggestion_id keys the stream when present: it is unique
        # service-wide, so speculative twins merge and two schedulers'
        # identically-numbered trials never collide
        key = req.suggestion_id or req.trial_id
        state.metric_seq += 1
        rec = {"seq": state.metric_seq, "trial_key": key,
               "trial_id": req.trial_id, "step": req.step,
               "value": req.value, "time": time.time()}
        if req.metadata:
            rec["metadata"] = req.metadata
        self.store.append_metric(exp_id, key, rec)
        if state.stopper is None:
            return Decision(next_rung=None, seq=state.metric_seq)
        decision = state.stopper.report(key, req.step, req.value)
        self._snapshot_rungs(exp_id, state)
        if decision == DECISION_STOP:
            # final prune: the stream is closed — drop its handle
            self._evict_trial_handles(exp_id, key)
        return Decision(decision,
                        next_rung=state.stopper.next_rung(key),
                        seq=state.metric_seq)

    def release(self, exp_id: str, suggestion_id: str) -> bool:
        state = self._state(exp_id)
        with state.lock:
            s = state.pending.pop(suggestion_id, None)
            state.sparse_ids.discard(suggestion_id)
            if s is not None:
                # never coming back: let the optimizer drop its
                # constant-liar bookkeeping for this point
                state.ops.append(("forget", s.assignment))
            pump = state.pump
        if s is not None:
            if pump is not None and pump.alive:
                pump.wake()
            else:
                self._drain_sync(state)
        return s is not None

    def requeue(self, exp_id: str, suggestion_id: str,
                assignment: Optional[Dict] = None) -> bool:
        """Dead-worker recovery (fleet event loop): park a *pending*
        suggestion for re-serving.  Unlike ``release`` the suggestion
        keeps its id and its constant-liar lie — the next ``suggest``
        hands it (exactly once) to a surviving worker, so the optimizer
        sees no retraction and the observation, whoever produces it,
        dedupes by the same suggestion_id.

        With ``assignment`` this is the *transfer* form (rebalance
        handover): a suggestion id minted by the previous owner is
        installed here as a parked pending under the same id, so the
        in-flight trial's eventual observation still lands exactly
        once."""
        state = self._state(exp_id)
        with state.lock:
            return self._requeue_locked(state, suggestion_id, assignment)

    @staticmethod
    def _requeue_locked(state: _ExperimentState, suggestion_id: str,
                        assignment: Optional[Dict] = None) -> bool:
        """Body of :meth:`requeue` (``state.lock`` held) — shared with
        the batched apply path."""
        s = state.pending.get(suggestion_id)
        if (s is None and assignment is not None
                and suggestion_id not in state.closed
                and not state.stopped):
            s = Suggestion(suggestion_id, assignment)
            state.pending[suggestion_id] = s
        if s is None or suggestion_id in state.closed or state.stopped:
            return False
        if all(o.suggestion_id != suggestion_id
               for o in state.orphaned):
            state.orphaned.append(s)
            state.stats["requeued"] += 1
        return True

    # ------------------------------------------------------------- batching
    def apply_batch(self, req: BatchRequest) -> BatchResponse:
        """Apply one ordered op batch (API.md §Transport batching) with
        exactly-once replay: the first delivery of a ``batch_id`` applies
        and records its per-op results; any redelivery (transport retry
        after a lost response) answers the recorded results with
        ``replayed=True`` instead of re-applying.  The window is bounded
        (``BATCH_DEDUPE_WINDOW``) — retries are prompt, so only the
        recent past needs remembering."""
        my_ev = None
        with self._batch_lock:
            ent = self._batches.get(req.batch_id)
            if ent is None:
                my_ev = threading.Event()
                self._batches[req.batch_id] = ("inflight", my_ev)
            elif ent[0] == "done":
                return BatchResponse(req.batch_id, ent[1].results,
                                     replayed=True)
        if my_ev is None:
            # concurrent redelivery while the first is still applying:
            # wait for it rather than racing a second application
            ent[1].wait(timeout=60.0)
            with self._batch_lock:
                ent = self._batches.get(req.batch_id)
            if ent is not None and ent[0] == "done":
                return BatchResponse(req.batch_id, ent[1].results,
                                     replayed=True)
            raise ApiError(E_INTERNAL,
                           f"batch {req.batch_id}: first delivery failed")
        try:
            resp = self._apply_batch(req)
        except BaseException:
            with self._batch_lock:
                self._batches.pop(req.batch_id, None)
            my_ev.set()
            raise
        with self._batch_lock:
            self._batches[req.batch_id] = ("done", resp)
            done = [k for k, v in self._batches.items() if v[0] == "done"]
            for k in done[:max(0, len(done) - BATCH_DEDUPE_WINDOW)]:
                self._batches.pop(k, None)
        my_ev.set()
        return resp

    _BATCH_PARSERS = {"observe": ObserveRequest, "report": ReportRequest,
                      "release": ReleaseRequest, "requeue": RequeueRequest}

    def _apply_batch(self, req: BatchRequest) -> BatchResponse:
        """Group ops per experiment (preserving in-batch order) and apply
        each group with one lock acquisition per phase instead of one
        per op."""
        results: List[Optional[BatchOpResult]] = [None] * len(req.ops)
        groups: Dict[str, List] = {}
        for i, op in enumerate(req.ops):
            try:
                parsed = self._BATCH_PARSERS[op.op].from_json(op.payload)
            except ApiError as e:
                results[i] = BatchOpResult.failure(op.seq, e)
                continue
            groups.setdefault(parsed.exp_id, []).append((i, op, parsed))
        for exp_id, items in groups.items():
            self._apply_group(exp_id, items, results)
        return BatchResponse(req.batch_id, [
            r if r is not None else BatchOpResult.failure(
                op.seq, ApiError(E_INTERNAL, "op not processed"))
            for r, op in zip(results, req.ops)])

    def _apply_group(self, exp_id: str, items: List,
                     results: List[Optional[BatchOpResult]]) -> None:
        def fail_all(err: ApiError) -> None:
            for i, op, _ in items:
                if results[i] is None:
                    results[i] = BatchOpResult.failure(op.seq, err)

        try:
            state = self._state(exp_id)
        except ApiError as e:
            fail_all(e)
            return
        # ONE fence check per group (one cached stat amortized over the
        # whole group, vs one per unbatched call).  A fenced zombie's
        # group is rejected item-by-item with typed ``fenced`` results —
        # no op is half-applied.
        if state.fenced or any(op.op in ("observe", "report")
                               for _, op, _ in items):
            try:
                self._check_fence(exp_id, state)
            except ApiError as e:
                fail_all(e)
                return
        accepted: List = []      # observes that passed bookkeeping
        deferred = False         # any tell/forget enqueued this group
        # phase 1 — bookkeeping for the whole group under ONE lock
        # acquisition, in batch order (per-experiment ordering contract)
        with state.lock:
            for i, op, r in items:
                if op.op == "observe":
                    if r.suggestion_id in state.closed:
                        results[i] = BatchOpResult.success(
                            op.seq, ObserveResponse(
                                accepted=False, duplicate=True,
                                observations=state.observed).to_json())
                    elif state.stopped:
                        results[i] = BatchOpResult.success(
                            op.seq, ObserveResponse(
                                accepted=False, duplicate=False,
                                observations=state.observed).to_json())
                    else:
                        state.closed.add(r.suggestion_id)
                        obs = Observation(r.assignment, r.value, r.stddev,
                                          r.failed, dict(r.metadata))
                        # deferred fold, enqueued before the log append —
                        # same exactly-once contract as observe()
                        state.ops.append(("tell", obs))
                        state.appends += 1
                        deferred = True
                        accepted.append((i, op, r, obs))
                elif op.op == "report":
                    try:
                        d = self._report_locked(exp_id, state, r)
                        results[i] = BatchOpResult.success(op.seq,
                                                           d.to_json())
                    except ApiError as e:
                        results[i] = BatchOpResult.failure(op.seq, e)
                elif op.op == "release":
                    released = False
                    # an observe earlier in this batch may have closed
                    # the id (its pending-pop lands in phase 3): the
                    # closed set is the authority, same as observe dedupe
                    if r.suggestion_id not in state.closed:
                        s = state.pending.pop(r.suggestion_id, None)
                        state.sparse_ids.discard(r.suggestion_id)
                        if s is not None:
                            state.ops.append(("forget", s.assignment))
                            deferred = True
                            released = True
                    results[i] = BatchOpResult.success(
                        op.seq, ReleaseResponse(released=released).to_json())
                else:   # requeue
                    ok = self._requeue_locked(state, r.suggestion_id,
                                              r.assignment)
                    results[i] = BatchOpResult.success(op.seq,
                                                       {"requeued": ok})
        # phase 2 — system-of-record appends OUTSIDE the lock (the store
        # serializes its own handles), exactly like observe()
        appended: List = []
        for i, op, r, obs in accepted:
            try:
                self.store.append_observation(exp_id, obs, r.trial_id,
                                              suggestion_id=r.suggestion_id)
                appended.append((i, op, r, obs))
            except BaseException as e:
                results[i] = BatchOpResult.failure(
                    op.seq, e if isinstance(e, ApiError) else
                    ApiError(E_INTERNAL, f"{type(e).__name__}: {e}"))
        # phase 3 — accounting for the whole group under ONE lock
        # acquisition; per-op responses see the progressive totals
        fields = None
        complete = False
        with state.lock:
            for i, op, r, obs in appended:
                state.pending.pop(r.suggestion_id, None)
                state.observed += 1
                if r.failed:
                    state.failures += 1
                was_sparse = r.suggestion_id in state.sparse_ids
                state.sparse_ids.discard(r.suggestion_id)
                if not obs.failed and obs.value is not None:
                    regret = (max(0.0, state.best.value - obs.value)
                              if state.best is not None else 0.0)
                    bucket = "sparse" if was_sparse else "exact"
                    state.stats[bucket + "_obs"] += 1
                    state.stats[bucket + "_regret"] += regret
                if (not obs.failed and obs.value is not None
                        and (state.best is None
                             or obs.value > state.best.value)):
                    state.best = obs
                results[i] = BatchOpResult.success(
                    op.seq, ObserveResponse(
                        accepted=True, duplicate=False,
                        observations=state.observed).to_json())
            if accepted:
                state.appends -= len(accepted)
                state.append_cv.notify_all()
            if appended:
                fields = dict(observations=state.observed,
                              failures=state.failures,
                              best=_public_best(state.best))
                complete = state.observed >= state.cfg.budget
            pump = state.pump
        # phase 4 — ONE coalesced status-mirror write per batch group
        # (terminal transitions bypass the throttle and always write)
        if fields is not None:
            if complete:
                fields["state"] = "complete"
                self.store.update_status(exp_id, **fields)
            else:
                self._mirror_status(exp_id, state, fields)
        for i, op, r, obs in appended:
            self._evict_trial_handles(exp_id, r.suggestion_id, r.trial_id)
        if deferred:
            if pump is not None and pump.alive:
                pump.wake()     # one wake per group, not per op
            else:
                self._drain_sync(state)

    def drain(self, exp_id: str) -> DrainResponse:
        """Quiesce + hand over one experiment (rebalance control plane):
        stop its pump, fold deferred observations, retire the
        speculative queue, drop the live state, and answer with the
        still-pending suggestions so the manager can transfer them to
        the new owner.  Leaves a tombstone so later routed calls get
        ``wrong_shard`` (refresh your map), not ``unknown_experiment``
        (which would invite clients to re-adopt here).  Idempotent."""
        with self._lock:
            state = self._exps.get(exp_id)
            if state is None:
                self._tombstone(exp_id)
                return DrainResponse(drained=False, pending=[],
                                     observations=0)
        with state.lock:
            pump = state.pump
        if pump is not None:
            pump.stop(join=True)    # no speculation past the handover
        with state.opt_lock:
            drain_ops(state)        # folds are real data — keep them
            retire_queue(state)     # flush speculative constant-liar lies
            with state.lock:
                pending = sorted(
                    (s for s in state.pending.values()
                     if s.suggestion_id not in state.closed),
                    key=lambda s: s.suggestion_id)
                slots, state.miss_slots = state.miss_slots, []
                for sl in slots:
                    sl.done = True
                    sl.event.set()
                observed = state.observed
        with self._lock:
            self._exps.pop(exp_id, None)
            self._tombstone(exp_id)
        return DrainResponse(drained=True, pending=pending,
                             observations=observed)

    def load(self) -> Dict:
        """Shard-level load summary — the fleet's admission-control
        signal: live experiment count, total pending, and the shared
        FitExecutor's queue depth (``backlog``) + recent duty cycle."""
        with self._lock:
            states = list(self._exps.values())
        live = pending = prefetched = 0
        for st in states:
            with st.lock:
                if not st.stopped and st.observed < st.cfg.budget:
                    live += 1
                pending += len(st.pending)
                prefetched += len(st.queue)
        ex = pipeline.executor_snapshot() or {}
        return {"experiments": len(states), "live": live,
                "pending": pending, "prefetched": prefetched,
                "backlog": int(ex.get("backlog", 0)),
                "duty": float(ex.get("duty", 0.0)),
                "executor": ex or None}

    # -------------------------------------------------------------- queries
    def status(self, exp_id: str) -> StatusResponse:
        with self._lock:
            state = self._exps.get(exp_id)
        if state is None:
            return self._status_from_store(exp_id)
        # freshness + terminal hygiene: fold deferred observations, and
        # once the experiment can't serve again (stopped / budget spent)
        # retire the speculative queue's constant-liar lies.  Skipped
        # entirely when there is nothing to do — the common monitoring
        # read stays off the optimizer lock (a pump mid-fit must not
        # stall a GET /status).
        with state.lock:
            dirty = bool(state.ops) or bool(
                state.queue and (state.stopped
                                 or state.observed >= state.cfg.budget))
        if dirty:
            with state.opt_lock:
                drain_ops(state)
                retire_queue(state, terminal_only=True)
        with state.lock:
            st = self.store.get_status(exp_id)
            pump = state.pump
            pump_stats = dict(state.stats,
                              alive=bool(pump is not None and pump.alive),
                              depth=state.pump_depth())
            # refit-schedule observability (ISSUE 5): the adaptive warm-
            # step / refit-period schedule and the shared fit executor's
            # counters ride along in the pump stats (additive fields)
            schedule = state.optimizer.refit_schedule()
            if schedule is not None:
                pump_stats["refit"] = schedule
            # sparse-vs-exact serving quality (mean instantaneous regret
            # on finished trials) — the SPARSE_MAX tuning readout
            n_s, n_e = state.stats["sparse_obs"], state.stats["exact_obs"]
            pump_stats["quality"] = {
                "sparse_n": n_s, "exact_n": n_e,
                "sparse_mean_regret": (
                    round(state.stats["sparse_regret"] / n_s, 6)
                    if n_s else None),
                "exact_mean_regret": (
                    round(state.stats["exact_regret"] / n_e, 6)
                    if n_e else None),
                # live auto-tuned sparse-subset budget (closes the PR 5
                # follow-up: the pump feeds these regret counters back
                # through Optimizer.tune_sparse each tick)
                "sparse_max": getattr(
                    state.optimizer, "_sparse_max", None)}
            if pump is not None:
                # None until a fit was actually submitted — a monitoring
                # read must not spawn the executor's worker pool
                pump_stats["executor"] = pipeline.executor_snapshot()
            return StatusResponse(
                exp_id=exp_id, state=st.get("state", "pending"),
                name=state.cfg.name, budget=state.cfg.budget,
                observations=state.observed, failures=state.failures,
                pending=len(state.pending),
                best=_public_best(state.best),
                prefetched=len(state.queue), pump=pump_stats,
                epoch=(list(state.epoch)
                       if state.epoch != EPOCH_ZERO else None))

    def _status_from_store(self, exp_id: str) -> StatusResponse:
        """Cold path: experiment not live in this process — answer from
        the system of record (works across process restarts)."""
        try:
            cfg = self.store.load_config(exp_id)
        except FileNotFoundError:
            raise ApiError(E_UNKNOWN_EXPERIMENT, f"no experiment {exp_id!r}")
        st = self.store.get_status(exp_id)
        obs = self.store.load_observations(exp_id)
        ok = [o for o in obs if not o.failed and o.value is not None]
        best = max(ok, key=lambda o: o.value) if ok else None
        return StatusResponse(
            exp_id=exp_id, state=st.get("state", "pending"), name=cfg.name,
            budget=cfg.budget, observations=len(obs),
            failures=sum(1 for o in obs if o.failed), pending=0,
            best=_public_best(best))

    def stop(self, exp_id: str, state: str = "stopped") -> StatusResponse:
        with self._lock:
            exp = self._exps.get(exp_id)
        if exp is not None:
            # stop writes a terminal status — fenced incarnations don't
            # get to flip a handed-over experiment's durable state
            self._check_fence(exp_id, exp)
            with exp.lock:
                exp.stopped = True
                pump = exp.pump
            if pump is not None:
                pump.stop(join=True)    # no new speculation after this
            with exp.opt_lock:
                drain_ops(exp)          # folds are real data — keep them
                retire_queue(exp)       # stopped: flush unconditionally
                with exp.lock:
                    doomed = [s.assignment for s in exp.pending.values()]
                    exp.pending.clear()
                    exp.orphaned.clear()
                    exp.sparse_ids.clear()
                    # unblock any parked miss slots with empty batches
                    slots, exp.miss_slots = exp.miss_slots, []
                    for sl in slots:
                        sl.done = True
                        sl.event.set()
                for a in doomed:
                    exp.optimizer.forget(a)
        elif not (self.store.exp_dir(exp_id) / "config.json").exists():
            raise ApiError(E_UNKNOWN_EXPERIMENT, f"no experiment {exp_id!r}")
        self.store.update_status(exp_id, state=state)
        return self.status(exp_id)

    def best_response(self, exp_id: str) -> BestResponse:
        return BestResponse(best=self.status(exp_id).best)

    def close(self) -> None:
        """Wind down every experiment's pump (service shutdown).  Leaves
        experiment state resumable: a later ``suggest``/``create`` simply
        restarts the pump."""
        with self._lock:
            states = list(self._exps.values())
        for st in states:
            with st.lock:
                pump = st.pump
            if pump is not None:
                pump.stop(join=True)
