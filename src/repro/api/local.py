"""In-process suggestion-service backend.

``LocalClient`` owns what the scheduler used to reach into directly: the
optimizer (via ``make_optimizer``) and the system-of-record ``Store``.
All state transitions are lock-guarded, and every handed-out assignment is
tracked as a *pending suggestion*, so concurrent ``suggest`` calls from
parallel workers never receive duplicate assignments and never
oversubscribe the observation budget.

This same object is also the backend behind ``serve_api`` — the HTTP layer
is a thin JSON shim over a ``LocalClient``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, Optional, Set, Union

from repro.api.client import SuggestionClient
from repro.api.protocol import (ApiError, BestResponse, CreateExperiment,
                                CreateResponse, DECISION_STOP, Decision,
                                E_UNKNOWN_EXPERIMENT, ObserveRequest,
                                ObserveResponse, ReportRequest,
                                StatusResponse, SuggestBatch, Suggestion)
from repro.core.experiment import ExperimentConfig
from repro.core.space import strip_internal
from repro.core.store import Store
from repro.core.suggest.base import (Observation, Optimizer, StoppingPolicy,
                                     make_optimizer, make_stopping_policy)


class _ExperimentState:
    """Live service-side state for one experiment (pending set is
    in-memory only; a service restart reclaims all pending budget —
    early-stopping rung state, by contrast, IS durable: snapshot in the
    experiment record + replay of the per-trial metric logs)."""

    def __init__(self, cfg: ExperimentConfig, optimizer: Optimizer,
                 stopper: Optional[StoppingPolicy] = None):
        self.cfg = cfg
        self.optimizer = optimizer
        self.stopper = stopper
        self.lock = threading.RLock()
        self.pending: Dict[str, Suggestion] = {}
        self.closed: Set[str] = set()
        self.observed = 0
        self.failures = 0
        self.stopped = False
        self.metric_seq = 0          # high-water mark of the metric stream
        self._seq = 0
        self._snap_version = -1      # stopper.version last persisted

    def next_suggestion_id(self) -> str:
        self._seq += 1
        return f"s{self._seq:05d}"


def _public_best(best) -> Optional[Dict]:
    """Serialize a best observation for user-facing readouts, stripping
    internal ``__``-prefixed echo keys (constant-liar tokens, particle
    ids) from the assignment."""
    if best is None:
        return None
    d = best.to_json()
    if isinstance(d.get("assignment"), dict):
        d["assignment"] = strip_internal(d["assignment"])
    return d


class LocalClient(SuggestionClient):
    def __init__(self, store: Union[Store, str]):
        self.store = store if isinstance(store, Store) else Store(store)
        self._exps: Dict[str, _ExperimentState] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    def create_experiment(self, req: CreateExperiment) -> CreateResponse:
        cfg = ExperimentConfig.from_json(req.config)
        exp_id = req.exp_id
        with self._lock:
            on_disk = (exp_id is not None
                       and (self.store.exp_dir(exp_id) / "config.json")
                       .exists())
            state = self._exps.get(exp_id) if exp_id else None
            if state is None:
                if exp_id is None:
                    from repro.core.experiment import new_experiment_id
                    exp_id = new_experiment_id()
                if not on_disk:
                    self.store.create_experiment(exp_id, cfg)
                optimizer = make_optimizer(cfg.optimizer, cfg.space,
                                           seed=cfg.seed,
                                           **cfg.optimizer_options)
                stopper = (make_stopping_policy(cfg.early_stop, goal=cfg.goal)
                           if cfg.early_stop else None)
                state = _ExperimentState(cfg, optimizer, stopper)
                # grab the experiment lock BEFORE publishing so no
                # concurrent suggest() sees observed=0 pre-replay
                state.lock.acquire()
                self._exps[exp_id] = state
            else:
                state.lock.acquire()
            resumed = on_disk or state.observed > 0
        try:
            state.cfg = cfg          # resume may raise the budget
            state.stopped = False    # re-creating declares intent to run
            if resumed:
                # keep the stored config in sync with the resumed one
                (self.store.exp_dir(exp_id) / "config.json").write_text(
                    json.dumps(cfg.to_json(), indent=1))
            prior = self.store.load_observations(exp_id)
            # restore() is idempotent: only the log tail beyond what the
            # optimizer has already absorbed is replayed
            state.optimizer.restore(
                {"history": [o.to_json() for o in prior]})
            state.observed = len(prior)
            state.failures = sum(1 for o in prior if o.failed)
            self._restore_rungs(exp_id, state, cfg)
        finally:
            state.lock.release()
        return CreateResponse(exp_id=exp_id, resumed=resumed,
                              observations=state.observed)

    def _restore_rungs(self, exp_id: str, state: _ExperimentState,
                       cfg: ExperimentConfig) -> None:
        """Resume trial-events state exactly like the observation log:
        load the rung snapshot from the experiment record, replay the
        metric-log tail beyond its ``seq`` high-water mark (crash between
        a metric append and the snapshot write), and advance ``metric_seq``
        past everything on disk so post-restart reports never reuse seq
        numbers — even for experiments with no stopping policy.
        Idempotent — a live state's absorbed stream is never replayed
        twice."""
        if cfg.early_stop and state.stopper is None:
            state.stopper = make_stopping_policy(cfg.early_stop,
                                                 goal=cfg.goal)
        if state.stopper is not None and state.metric_seq == 0:
            snap = self.store.get_status(exp_id).get("rungs")
            if snap:
                state.stopper.restore(snap)
                state.metric_seq = int(snap.get("seq", 0))
                state._snap_version = state.stopper.version
        records = self.store.load_metrics(exp_id)
        tail = [r for r in records if r.get("seq", 0) > state.metric_seq]
        if state.stopper is not None:
            for r in tail:
                state.stopper.report(
                    r.get("trial_key") or r.get("trial_id", ""),
                    int(r["step"]), float(r["value"]))
        if records:
            state.metric_seq = max(
                state.metric_seq,
                max(int(r.get("seq", 0)) for r in records))
        if tail:
            self._snapshot_rungs(exp_id, state)

    def _snapshot_rungs(self, exp_id: str, state: _ExperimentState) -> None:
        """Persist the rung table into the experiment record (status.json)
        whenever it actually changed — reports between rungs don't touch
        policy state and stay off this path."""
        if state.stopper is None or state.stopper.version == state._snap_version:
            return
        snap = dict(state.stopper.state(), seq=state.metric_seq)
        state._snap_version = state.stopper.version
        self.store.update_status(exp_id, rungs=snap)

    def _state(self, exp_id: str) -> _ExperimentState:
        with self._lock:
            state = self._exps.get(exp_id)
        if state is None:
            raise ApiError(E_UNKNOWN_EXPERIMENT,
                           f"no live experiment {exp_id!r}")
        return state

    # ------------------------------------------------------ suggest/observe
    def suggest(self, exp_id: str, count: int = 1) -> SuggestBatch:
        state = self._state(exp_id)
        with state.lock:
            if state.stopped:
                return SuggestBatch([], remaining=0)
            headroom = (state.cfg.budget - state.observed
                        - len(state.pending))
            n = max(0, min(count, headroom))
            batch = []
            if n:
                for a in state.optimizer.ask(n):
                    s = Suggestion(state.next_suggestion_id(), a)
                    state.pending[s.suggestion_id] = s
                    batch.append(s)
            remaining = (state.cfg.budget - state.observed
                         - len(state.pending))
            return SuggestBatch(batch, remaining=max(0, remaining))

    def observe(self, req: ObserveRequest) -> ObserveResponse:
        state = self._state(req.exp_id)
        with state.lock:
            if req.suggestion_id in state.closed:
                return ObserveResponse(accepted=False, duplicate=True,
                                       observations=state.observed)
            if state.stopped:
                # stopped/deleted experiments take no more observations
                # (a straggler must not flip 'deleted' back to 'complete')
                return ObserveResponse(accepted=False, duplicate=False,
                                       observations=state.observed)
            # tolerate untracked ids (service restart lost the pending set)
            state.pending.pop(req.suggestion_id, None)
            state.closed.add(req.suggestion_id)
            obs = Observation(req.assignment, req.value, req.stddev,
                              req.failed, dict(req.metadata))
            state.optimizer.tell([obs])
            self.store.append_observation(req.exp_id, obs, req.trial_id)
            state.observed += 1
            if req.failed:
                state.failures += 1
            best = state.optimizer.best()
            fields = dict(observations=state.observed,
                          failures=state.failures,
                          best=_public_best(best))
            if state.observed >= state.cfg.budget:
                fields["state"] = "complete"
            self.store.update_status(req.exp_id, **fields)
            return ObserveResponse(accepted=True, duplicate=False,
                                   observations=state.observed)

    def report(self, req: ReportRequest) -> Decision:
        """Trial-events hot path: append the progress point to the trial's
        metric stream, run it through the experiment's (shared) stopping
        policy, and answer continue/stop/pause.  Single-writer under the
        experiment lock — N schedulers prune against ONE rung table."""
        state = self._state(req.exp_id)
        with state.lock:
            if state.stopped:
                # deleted/stopped experiments wind their trials down via
                # the next report, even without a worker-side stop flag
                return Decision(DECISION_STOP, next_rung=None,
                                seq=state.metric_seq)
            # suggestion_id keys the stream when present: it is unique
            # service-wide, so speculative twins merge and two schedulers'
            # identically-numbered trials never collide
            key = req.suggestion_id or req.trial_id
            state.metric_seq += 1
            rec = {"seq": state.metric_seq, "trial_key": key,
                   "trial_id": req.trial_id, "step": req.step,
                   "value": req.value, "time": time.time()}
            if req.metadata:
                rec["metadata"] = req.metadata
            self.store.append_metric(req.exp_id, key, rec)
            if state.stopper is None:
                return Decision(next_rung=None, seq=state.metric_seq)
            decision = state.stopper.report(key, req.step, req.value)
            self._snapshot_rungs(req.exp_id, state)
            return Decision(decision,
                            next_rung=state.stopper.next_rung(key),
                            seq=state.metric_seq)

    def release(self, exp_id: str, suggestion_id: str) -> bool:
        state = self._state(exp_id)
        with state.lock:
            s = state.pending.pop(suggestion_id, None)
            if s is not None:
                # never coming back: let the optimizer drop its
                # constant-liar bookkeeping for this point
                state.optimizer.forget(s.assignment)
            return s is not None

    # -------------------------------------------------------------- queries
    def status(self, exp_id: str) -> StatusResponse:
        with self._lock:
            state = self._exps.get(exp_id)
        if state is not None:
            with state.lock:
                st = self.store.get_status(exp_id)
                best = state.optimizer.best()
                return StatusResponse(
                    exp_id=exp_id, state=st.get("state", "pending"),
                    name=state.cfg.name, budget=state.cfg.budget,
                    observations=state.observed, failures=state.failures,
                    pending=len(state.pending),
                    best=_public_best(best))
        return self._status_from_store(exp_id)

    def _status_from_store(self, exp_id: str) -> StatusResponse:
        """Cold path: experiment not live in this process — answer from
        the system of record (works across process restarts)."""
        try:
            cfg = self.store.load_config(exp_id)
        except FileNotFoundError:
            raise ApiError(E_UNKNOWN_EXPERIMENT, f"no experiment {exp_id!r}")
        st = self.store.get_status(exp_id)
        obs = self.store.load_observations(exp_id)
        ok = [o for o in obs if not o.failed and o.value is not None]
        best = max(ok, key=lambda o: o.value) if ok else None
        return StatusResponse(
            exp_id=exp_id, state=st.get("state", "pending"), name=cfg.name,
            budget=cfg.budget, observations=len(obs),
            failures=sum(1 for o in obs if o.failed), pending=0,
            best=_public_best(best))

    def stop(self, exp_id: str, state: str = "stopped") -> StatusResponse:
        with self._lock:
            exp = self._exps.get(exp_id)
        if exp is not None:
            with exp.lock:
                exp.stopped = True
                for s in exp.pending.values():
                    exp.optimizer.forget(s.assignment)
                exp.pending.clear()
        elif not (self.store.exp_dir(exp_id) / "config.json").exists():
            raise ApiError(E_UNKNOWN_EXPERIMENT, f"no experiment {exp_id!r}")
        self.store.update_status(exp_id, state=state)
        return self.status(exp_id)

    def best_response(self, exp_id: str) -> BestResponse:
        return BestResponse(best=self.status(exp_id).best)
