# Suggestion-service API (v1): the typed suggest/observe boundary between
# trial execution and the optimizer + system-of-record store.  See API.md.
from repro.api.client import SuggestionClient
from repro.api.http import ApiServer, HTTPClient, serve_api
from repro.api.local import LocalClient
from repro.api.protocol import (ApiError, BestRequest, BestResponse,
                                CreateExperiment, CreateResponse,
                                ObserveRequest, ObserveResponse,
                                PROTOCOL_VERSION, ReleaseRequest,
                                ReleaseResponse, StatusRequest,
                                StatusResponse, StopRequest, SuggestBatch,
                                Suggestion, SuggestRequest)

__all__ = ["SuggestionClient", "LocalClient", "HTTPClient", "ApiServer",
           "serve_api", "ApiError", "PROTOCOL_VERSION", "CreateExperiment",
           "CreateResponse", "Suggestion", "SuggestRequest", "SuggestBatch",
           "ObserveRequest", "ObserveResponse", "ReleaseRequest",
           "ReleaseResponse", "StatusRequest", "StatusResponse",
           "StopRequest", "BestRequest", "BestResponse"]
