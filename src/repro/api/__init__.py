# Suggestion-service API (v1): the typed suggest/observe/report boundary
# between trial execution and the optimizer + system-of-record store.
# See API.md.
from repro.api.client import SuggestionClient
from repro.api.http import ApiServer, HTTPClient, serve_api
from repro.api.local import LocalClient
from repro.api.protocol import (DECISION_CONTINUE, DECISION_PAUSE,
                                DECISION_STOP, ApiError, BestRequest,
                                BestResponse, CreateExperiment,
                                CreateResponse, Decision, ObserveRequest,
                                ObserveResponse, PROTOCOL_VERSION,
                                ReleaseRequest, ReleaseResponse,
                                ReportRequest, StatusRequest, StatusResponse,
                                StopRequest, SuggestBatch, Suggestion,
                                SuggestRequest)

__all__ = ["SuggestionClient", "LocalClient", "HTTPClient", "ApiServer",
           "serve_api", "ApiError", "PROTOCOL_VERSION", "CreateExperiment",
           "CreateResponse", "Suggestion", "SuggestRequest", "SuggestBatch",
           "ObserveRequest", "ObserveResponse", "ReportRequest", "Decision",
           "DECISION_CONTINUE", "DECISION_STOP", "DECISION_PAUSE",
           "ReleaseRequest", "ReleaseResponse", "StatusRequest",
           "StatusResponse", "StopRequest", "BestRequest", "BestResponse"]
