"""Client-side write-behind batching plane (API.md §Transport batching).

The fleet hot path is dominated by small fire-and-forget data-plane
calls — observe, release, and the below-rung majority of reports.  Tune
(arxiv 1807.05118) treats this traffic as a stream to be amortized, not
per-call RPC; :class:`WriteBehind` is that stream's client half.  Ops are
enqueued into per-*lane* FIFO queues (one lane per destination — a plain
``HTTPClient`` has one lane, a ``FleetClient`` one per owning shard) and
a flusher thread ships each lane as ONE :class:`BatchRequest` when any
trigger fires:

* **size** — the lane reached ``max_ops`` queued ops;
* **deadline** — the lane's oldest op aged past ``deadline`` (~10 ms);
* **blocking call** — the owner calls :meth:`flush` before any verb that
  must observe queued effects (suggest / status / create / stop / a
  rung-crossing report), draining the queue on the caller's own
  keep-alive connection so per-experiment op order is preserved.

Exactly-once: every batch carries a client-unique ``batch_id`` and is
sent as an *idempotent* POST — the server keeps a bounded dedupe window
and replays the recorded per-op results if a transport retry re-delivers
an already-applied batch, so the full-jitter backoff machinery retries
whole batches safely.

Ops never carry waiters: a call that needs its real result (a report
that can cross an ASHA rung, per :class:`DecisionGate`) flushes the
queue and then issues the plain unbatched call — same ordering, no
parked threads inside the flusher.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.api.protocol import (ApiError, BatchOp, BatchRequest,
                                BatchResponse, DECISION_CONTINUE, Decision,
                                E_INTERNAL)

OP_OBSERVE = "observe"
OP_REPORT = "report"
OP_RELEASE = "release"
OP_REQUEUE = "requeue"

FLUSH_MAX_OPS = 64         # size trigger: ship a lane at this many ops
FLUSH_DEADLINE_S = 0.010   # age trigger: oldest queued op waits at most this
MAX_OP_ERRORS = 64         # bounded per-client record of failed ops

_ALL_LANES = object()      # flush() sentinel: drain every lane


class QueuedOp:
    """One enqueued fire-and-forget op.  ``attempts`` counts re-enqueues
    after per-op or whole-batch failures (the owner's ``on_result`` /
    ``on_send_failure`` hooks bound it)."""

    __slots__ = ("kind", "payload", "attempts", "enqueued_at")

    def __init__(self, kind: str, payload: Dict[str, Any], attempts: int = 0):
        self.kind = kind
        self.payload = payload
        self.attempts = attempts
        self.enqueued_at = time.monotonic()

    @property
    def exp_id(self) -> str:
        return self.payload.get("exp_id", "")


class WriteBehind:
    """Per-lane op queues + one flusher thread.

    ``send(lane, BatchRequest) -> BatchResponse`` is the owner's
    transport (it may raise ``ApiError`` after its own retries).
    ``on_result(lane, op, result, error) -> bool`` sees every op outcome
    — a ``BatchOpResult`` on success, an ``ApiError`` on per-op failure —
    and returns True when it fully handled the op (e.g. re-homed and
    re-enqueued it); unhandled failures land in ``stats``/``op_errors``.
    ``on_send_failure(lane, ops, exc) -> bool`` likewise for a whole
    batch that never got a response.  ``after_flush()`` runs once per
    shipped batch (heartbeat piggyback hook)."""

    def __init__(self, send: Callable[[Any, BatchRequest], BatchResponse],
                 max_ops: int = FLUSH_MAX_OPS,
                 deadline: float = FLUSH_DEADLINE_S,
                 on_result: Optional[Callable] = None,
                 on_send_failure: Optional[Callable] = None,
                 after_flush: Optional[Callable[[], None]] = None,
                 name: str = "write-behind"):
        self._send = send
        self.max_ops = max(1, int(max_ops))
        self.deadline = max(0.0, float(deadline))
        self._on_result = on_result
        self._on_send_failure = on_send_failure
        self._after_flush = after_flush
        self._name = name
        self._lanes: Dict[Any, List[QueuedOp]] = {}
        self._cv = threading.Condition(threading.Lock())
        # serializes batch sends: lane order is FIFO because at most one
        # flush (thread or blocking caller) is shipping at a time
        self._send_lock = threading.RLock()
        self._nonce = uuid.uuid4().hex[:8]
        self._batch_n = 0
        self._thread: Optional[threading.Thread] = None
        self._stopped = False
        self.stats = {"batches": 0, "ops": 0, "replayed": 0,
                      "op_errors": 0, "send_failures": 0}
        self.op_errors: List[dict] = []

    # ------------------------------------------------------------- enqueue
    def enqueue(self, kind: str, payload: Dict[str, Any],
                lane: Any = None, attempts: int = 0) -> QueuedOp:
        op = QueuedOp(kind, payload, attempts=attempts)
        with self._cv:
            if self._stopped:
                raise ApiError(E_INTERNAL, "write-behind is closed")
            self._lanes.setdefault(lane, []).append(op)
            self._ensure_thread()
            self._cv.notify_all()
        return op

    def depth(self, lane: Any = _ALL_LANES) -> int:
        with self._cv:
            if lane is _ALL_LANES:
                return sum(len(q) for q in self._lanes.values())
            return len(self._lanes.get(lane) or ())

    def _ensure_thread(self) -> None:
        # holding self._cv
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._loop,
                                            name=self._name, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- flushing
    def _loop(self) -> None:
        while True:
            with self._cv:
                live = [l for l, q in self._lanes.items() if q]
                if self._stopped and not live:
                    return
                now = time.monotonic()
                due, next_due = [], None
                for lane in live:
                    q = self._lanes[lane]
                    at = q[0].enqueued_at + self.deadline
                    if (len(q) >= self.max_ops or at <= now
                            or self._stopped):
                        due.append(lane)
                    elif next_due is None or at < next_due:
                        next_due = at
                if not due:
                    self._cv.wait(timeout=(max(0.0, next_due - now)
                                           if next_due is not None else 0.2))
                    continue
            for lane in due:
                self._flush_lane(lane)

    def flush(self, lane: Any = _ALL_LANES) -> None:
        """Drain synchronously on the calling thread (the blocking-verb
        trigger): every op queued at call time is shipped before this
        returns.  Empty queues return without touching the send lock —
        the common case once the deadline flusher has shipped, and a
        convoy point if callers serialized on it just to find nothing."""
        if lane is not _ALL_LANES:
            with self._cv:
                if not self._lanes.get(lane):
                    return
            self._flush_lane(lane)
            return
        while True:
            with self._cv:
                live = [l for l, q in self._lanes.items() if q]
            if not live:
                return
            for l in live:
                self._flush_lane(l)

    def _flush_lane(self, lane: Any) -> None:
        with self._send_lock:
            while True:
                with self._cv:
                    q = self._lanes.get(lane)
                    if not q:
                        return
                    ops = q[:self.max_ops]
                    self._lanes[lane] = q[self.max_ops:]
                self._ship(lane, ops)

    def _ship(self, lane: Any, ops: List[QueuedOp]) -> None:
        # holding self._send_lock
        self._batch_n += 1
        req = BatchRequest(f"b{self._nonce}-{self._batch_n}",
                           [BatchOp(i, op.kind, op.payload)
                            for i, op in enumerate(ops)])
        try:
            resp = self._send(lane, req)
        except BaseException as e:
            self.stats["send_failures"] += 1
            if self._on_send_failure is not None \
                    and self._on_send_failure(lane, ops, e):
                return
            err = (e if isinstance(e, ApiError)
                   else ApiError(E_INTERNAL, f"{type(e).__name__}: {e}"))
            for op in ops:
                self._record_failure(lane, op, err)
            return
        self.stats["batches"] += 1
        self.stats["ops"] += len(ops)
        if resp.replayed:
            self.stats["replayed"] += 1
        by_seq = {r.seq: r for r in resp.results}
        for i, op in enumerate(ops):
            r = by_seq.get(i)
            if r is None:
                self._record_failure(lane, op, ApiError(
                    E_INTERNAL, f"batch {req.batch_id}: no result for "
                                f"op seq {i}"))
            elif r.ok:
                if self._on_result is not None:
                    self._on_result(lane, op, r, None)
            else:
                self._record_failure(
                    lane, op, ApiError.from_json({"error": r.error or {}}),
                    result=r)
        if self._after_flush is not None:
            try:
                self._after_flush()
            except Exception:
                pass

    def _record_failure(self, lane: Any, op: QueuedOp, err: ApiError,
                        result=None) -> None:
        if self._on_result is not None \
                and self._on_result(lane, op, result, err):
            return
        self.stats["op_errors"] += 1
        self.op_errors.append({"op": op.kind, "exp_id": op.exp_id,
                               "code": err.code, "message": err.message})
        if len(self.op_errors) > MAX_OP_ERRORS:
            del self.op_errors[:MAX_OP_ERRORS // 2]

    def close(self) -> None:
        """Flush everything still queued, then stop the flusher."""
        with self._cv:
            self._stopped = True
            t = self._thread
            self._cv.notify_all()
        self.flush()
        if t is not None and t is not threading.current_thread():
            t.join(timeout=5.0)


# ---------------------------------------------------------- decision gate
_UNKNOWN = object()


class DecisionGate:
    """Which reports may ride the batch (API.md §Transport batching).

    The service's :class:`Decision.next_rung` is the smallest step at
    which the *next* report from a trial can change policy state; every
    report strictly below it is CONTINUE by construction and is safe to
    fire-and-forget.  A report blocks for its real decision when the
    cached rung is unknown (first report of a trial) or ``step >=
    next_rung`` (it can cross the rung).  ``next_rung is None`` — no
    early stopping configured — never blocks after the first report.

    A non-CONTINUE decision arriving on a *batched* result (the
    experiment was stopped out from under the trial) is stashed and
    delivered on that trial's next report, bounding wind-down latency to
    one report interval."""

    MAX_TRIALS = 4096      # bounded: evict oldest trial keys

    def __init__(self):
        self._lock = threading.Lock()
        self._rungs: Dict[Tuple[str, str], Optional[int]] = {}
        self._stash: Dict[Tuple[str, str], Decision] = {}

    @staticmethod
    def key(req) -> Tuple[str, str]:
        return (req.exp_id, req.suggestion_id or req.trial_id)

    def blocking(self, req) -> bool:
        with self._lock:
            rung = self._rungs.get(self.key(req), _UNKNOWN)
        if rung is _UNKNOWN:
            return True
        return rung is not None and int(req.step) >= int(rung)

    def note(self, key: Tuple[str, str], decision: Decision) -> None:
        with self._lock:
            self._rungs[key] = decision.next_rung
            while len(self._rungs) > self.MAX_TRIALS:
                self._rungs.pop(next(iter(self._rungs)))
            if decision.decision != DECISION_CONTINUE:
                self._stash[key] = decision
                while len(self._stash) > self.MAX_TRIALS:
                    self._stash.pop(next(iter(self._stash)))

    def take_stashed(self, req) -> Optional[Decision]:
        with self._lock:
            return self._stash.pop(self.key(req), None)

    def ride_decision(self, req) -> Decision:
        """Synthetic CONTINUE for a riding report (``seq=0`` marks it as
        client-synthesized — the real seq arrives with the batch)."""
        with self._lock:
            return Decision(DECISION_CONTINUE,
                            next_rung=self._rungs.get(self.key(req)), seq=0)
