"""Versioned suggestion-service wire protocol (v1).

The paper's workers drive a *suggestion service* through a narrow
suggest/observe loop (Orchestrate §2.1, §3.5).  This module is the typed
contract for that loop: every operation has a request and a response
dataclass with a stable JSON form, so the same messages flow through the
in-process ``LocalClient`` and the HTTP backend unchanged.

Operations (see API.md for the HTTP mapping):
  create   CreateExperiment  -> CreateResponse
  suggest  SuggestRequest    -> SuggestBatch
  observe  ObserveRequest    -> ObserveResponse
  report   ReportRequest     -> Decision
  release  ReleaseRequest    -> ReleaseResponse
  status   StatusRequest     -> StatusResponse
  stop     StopRequest       -> StatusResponse
  best     BestRequest       -> BestResponse

Pending-suggestion semantics: every assignment handed out by ``suggest``
carries a unique ``suggestion_id`` and stays *pending* until it is either
observed (exactly once — later observes are flagged duplicates) or
released.  The service never hands out more than
``budget - observations - pending`` new suggestions, so concurrent
workers can't oversubscribe the budget or receive the same pending
assignment twice.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

PROTOCOL_VERSION = "v1"

# ------------------------------------------------------------------ errors
E_BAD_REQUEST = "bad_request"                # 400
E_UNKNOWN_EXPERIMENT = "unknown_experiment"  # 404
E_UNKNOWN_SUGGESTION = "unknown_suggestion"  # 404
E_EXPERIMENT_EXISTS = "experiment_exists"    # 409
E_INTERNAL = "internal"                      # 500
E_FLEET_BUSY = "fleet_busy"                  # 503: every shard saturated
E_WRONG_SHARD = "wrong_shard"                # 421: routed past a map change
E_FENCED = "fenced"                          # 409: write carried a stale epoch

_HTTP_STATUS = {E_BAD_REQUEST: 400, E_UNKNOWN_EXPERIMENT: 404,
                E_UNKNOWN_SUGGESTION: 404, E_EXPERIMENT_EXISTS: 409,
                E_INTERNAL: 500, E_FLEET_BUSY: 503, E_WRONG_SHARD: 421,
                E_FENCED: 409}


# ------------------------------------------------------------------ epochs
# An ownership epoch is a ``[term, seq]`` pair compared lexicographically:
# ``term`` is the fleet manager's leadership term (bumped on every
# takeover, so a deposed manager's grants always lose) and ``seq`` is the
# manager's monotonically bumped grant counter (derived from the ShardMap
# version stream, so within one term a later handover always wins).  A
# standalone service runs at term 0.  See API.md §Fleet / Fencing.
EPOCH_ZERO = (0, 0)


def epoch_tuple(v) -> tuple:
    """Normalize a wire/storage epoch (2-list, tuple or None) to a
    comparable ``(term, seq)`` tuple of ints."""
    if v is None:
        return EPOCH_ZERO
    try:
        term, seq = v
        return (int(term), int(seq))
    except (TypeError, ValueError):
        raise ApiError(E_BAD_REQUEST, f"malformed epoch {v!r}")


class ApiError(Exception):
    """Service-level failure with a stable error code (API.md §Errors)."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message

    @property
    def http_status(self) -> int:
        return _HTTP_STATUS.get(self.code, 500)

    def to_json(self) -> Dict[str, Any]:
        return {"error": {"code": self.code, "message": self.message}}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ApiError":
        e = d.get("error", d)
        return cls(e.get("code", E_INTERNAL), e.get("message", ""))


# ----------------------------------------------------------------- messages
@dataclass
class CreateExperiment:
    """Create (or resume, when ``exp_id`` names an existing experiment).

    ``config`` may be empty *only* together with an ``exp_id``: the
    service then resumes the experiment from its stored config — the
    fleet failover path (a new owner shard adopts an experiment it has
    never seen, out of the shared system-of-record store).

    ``epoch`` is the manager-granted ownership epoch (``[term, seq]``,
    see module epoch helpers).  When present the adopting shard *claims*
    the experiment's fence record at that epoch, fencing every older
    incarnation; when absent the shard adopts at the stored epoch
    (standalone / same-map resume)."""
    config: Dict[str, Any]                  # ExperimentConfig.to_json()
    exp_id: Optional[str] = None
    epoch: Optional[List[int]] = None

    def to_json(self) -> Dict[str, Any]:
        return {"version": PROTOCOL_VERSION, "config": self.config,
                "exp_id": self.exp_id,
                "epoch": list(self.epoch) if self.epoch else None}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "CreateExperiment":
        if not d.get("config") and not d.get("exp_id"):
            raise ApiError(E_BAD_REQUEST, "create requires 'config'")
        epoch = d.get("epoch")
        if epoch is not None:
            epoch = list(epoch_tuple(epoch))
        return cls(config=d.get("config") or {}, exp_id=d.get("exp_id"),
                   epoch=epoch)


@dataclass
class CreateResponse:
    exp_id: str
    resumed: bool = False
    observations: int = 0                   # already in the log on resume

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "resumed": self.resumed,
                "observations": self.observations}

    @classmethod
    def from_json(cls, d) -> "CreateResponse":
        return cls(d["exp_id"], d.get("resumed", False),
                   d.get("observations", 0))


@dataclass
class Suggestion:
    """One pending assignment; observe/release it by ``suggestion_id``."""
    suggestion_id: str
    assignment: Dict[str, Any]

    def to_json(self) -> Dict[str, Any]:
        return {"suggestion_id": self.suggestion_id,
                "assignment": self.assignment}

    @classmethod
    def from_json(cls, d) -> "Suggestion":
        return cls(d["suggestion_id"], d["assignment"])


@dataclass
class SuggestRequest:
    exp_id: str
    count: int = 1

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "count": self.count}

    @classmethod
    def from_json(cls, d) -> "SuggestRequest":
        count = int(d.get("count", 1))
        if count < 0:
            raise ApiError(E_BAD_REQUEST, f"count must be >= 0, got {count}")
        return cls(d.get("exp_id", ""), count)


@dataclass
class SuggestBatch:
    """May hold fewer than ``count`` suggestions: the service caps at
    ``budget - observations - pending`` (and returns none once stopped)."""
    suggestions: List[Suggestion] = field(default_factory=list)
    remaining: int = 0                      # budget headroom after this batch

    def __len__(self) -> int:
        return len(self.suggestions)

    def to_json(self) -> Dict[str, Any]:
        return {"suggestions": [s.to_json() for s in self.suggestions],
                "remaining": self.remaining}

    @classmethod
    def from_json(cls, d) -> "SuggestBatch":
        return cls([Suggestion.from_json(s) for s in d.get("suggestions", [])],
                   d.get("remaining", 0))


@dataclass
class ObserveRequest:
    """Report the outcome of one suggestion.  ``value`` is goal-normalized
    (maximize); ``failed=True`` with value None records a crash as data."""
    exp_id: str
    suggestion_id: str
    assignment: Dict[str, Any]
    value: Optional[float] = None
    stddev: float = 0.0
    failed: bool = False
    trial_id: str = ""
    metadata: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "suggestion_id": self.suggestion_id,
                "assignment": self.assignment, "value": self.value,
                "stddev": self.stddev, "failed": self.failed,
                "trial_id": self.trial_id, "metadata": self.metadata}

    @classmethod
    def from_json(cls, d) -> "ObserveRequest":
        if "suggestion_id" not in d or "assignment" not in d:
            raise ApiError(E_BAD_REQUEST,
                           "observe requires 'suggestion_id' + 'assignment'")
        return cls(d.get("exp_id", ""), d["suggestion_id"], d["assignment"],
                   d.get("value"), d.get("stddev", 0.0),
                   d.get("failed", False), d.get("trial_id", ""),
                   d.get("metadata", {}))


@dataclass
class ObserveResponse:
    accepted: bool
    duplicate: bool = False                 # suggestion was already observed
    observations: int = 0                   # experiment-wide total

    def to_json(self) -> Dict[str, Any]:
        return {"accepted": self.accepted, "duplicate": self.duplicate,
                "observations": self.observations}

    @classmethod
    def from_json(cls, d) -> "ObserveResponse":
        return cls(d.get("accepted", False), d.get("duplicate", False),
                   d.get("observations", 0))


# ----------------------------------------------------------- trial events
DECISION_CONTINUE = "continue"
DECISION_STOP = "stop"
DECISION_PAUSE = "pause"


@dataclass
class ReportRequest:
    """Intermediate trial progress: one (step, value) point of the metric
    stream.  ``value`` is the *raw* metric — the service applies the
    experiment goal when it evaluates early-stopping rungs.  The service
    appends every report to the trial's ``metrics.jsonl`` and answers with
    a :class:`Decision`."""
    exp_id: str
    trial_id: str
    step: int
    value: float
    suggestion_id: str = ""                 # ties the stream to a pending
    metadata: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "trial_id": self.trial_id,
                "step": self.step, "value": self.value,
                "suggestion_id": self.suggestion_id,
                "metadata": self.metadata}

    @classmethod
    def from_json(cls, d) -> "ReportRequest":
        if "step" not in d or "value" not in d:
            raise ApiError(E_BAD_REQUEST,
                           "report requires 'step' + 'value'")
        if not d.get("trial_id") and not d.get("suggestion_id"):
            raise ApiError(E_BAD_REQUEST,
                           "report requires 'trial_id' or 'suggestion_id'")
        try:
            step, value = int(d["step"]), float(d["value"])
        except (TypeError, ValueError):
            raise ApiError(E_BAD_REQUEST,
                           f"report step/value must be numeric, got "
                           f"{d['step']!r}/{d['value']!r}")
        return cls(d.get("exp_id", ""), d.get("trial_id", ""),
                   step, value,
                   d.get("suggestion_id", ""), d.get("metadata", {}))


@dataclass
class Decision:
    """Service verdict on a progress report.

    decision   continue | stop | pause.  ``stop`` is final (the trial is
               outside the top 1/eta at a rung it crossed); ``pause``
               releases the trial's resources but keeps its suggestion
               pending so it can be resumed from checkpoint when the rung
               population shifts in its favor (promotion).
    next_rung  smallest step at which the service needs the *next* report
               from this trial (None = no early stopping configured).
               Workers use it to throttle reports without ever skipping a
               rung boundary.
    seq        service-assigned position in the experiment-wide metric
               stream (monotone; the rung-snapshot high-water mark).
    """
    decision: str = DECISION_CONTINUE
    next_rung: Optional[int] = None
    seq: int = 0

    def to_json(self) -> Dict[str, Any]:
        return {"decision": self.decision, "next_rung": self.next_rung,
                "seq": self.seq}

    @classmethod
    def from_json(cls, d) -> "Decision":
        return cls(d.get("decision", DECISION_CONTINUE), d.get("next_rung"),
                   d.get("seq", 0))


@dataclass
class ReleaseRequest:
    """Return an unevaluated suggestion to the budget (worker shutdown)."""
    exp_id: str
    suggestion_id: str

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "suggestion_id": self.suggestion_id}

    @classmethod
    def from_json(cls, d) -> "ReleaseRequest":
        if "suggestion_id" not in d:
            raise ApiError(E_BAD_REQUEST, "release requires 'suggestion_id'")
        return cls(d.get("exp_id", ""), d["suggestion_id"])


@dataclass
class ReleaseResponse:
    released: bool

    def to_json(self) -> Dict[str, Any]:
        return {"released": self.released}

    @classmethod
    def from_json(cls, d) -> "ReleaseResponse":
        return cls(d.get("released", False))


@dataclass
class StatusRequest:
    exp_id: str

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id}

    @classmethod
    def from_json(cls, d) -> "StatusRequest":
        return cls(d.get("exp_id", ""))


@dataclass
class StatusResponse:
    """``prefetched``/``pump`` describe the suggestion pipeline (additive
    v1 fields, API.md §Suggestion pipeline): ``prefetched`` is the number
    of pre-computed suggestions currently warm in the prefetch queue, and
    ``pump`` carries the pump's counters (hits, misses, coalesced,
    invalidated, prefilled, sparse_prefilled, prewarmed, alive, depth —
    plus, for live experiments, the optimizer's ``refit`` schedule and
    the shared fit executor's ``executor`` counters, API.md §Posterior
    approximation & refit scheduling) or ``None`` for a non-live
    experiment.

    ``epoch`` is the serving shard's ownership epoch for the experiment
    (``[term, seq]``, additive v1 field); ``transport`` carries the
    *client-side* HTTP retry/backoff counters (filled in by
    ``HTTPClient.status``, never sent by the service — additive v1
    field, API.md §Errors / Retries)."""
    exp_id: str
    state: str = "pending"
    name: str = ""
    budget: int = 0
    observations: int = 0
    failures: int = 0
    pending: int = 0
    best: Optional[Dict[str, Any]] = None   # Observation.to_json()
    prefetched: int = 0
    pump: Optional[Dict[str, Any]] = None
    epoch: Optional[List[int]] = None
    transport: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "state": self.state, "name": self.name,
                "budget": self.budget, "observations": self.observations,
                "failures": self.failures, "pending": self.pending,
                "best": self.best, "prefetched": self.prefetched,
                "pump": self.pump,
                "epoch": list(self.epoch) if self.epoch else None}

    @classmethod
    def from_json(cls, d) -> "StatusResponse":
        epoch = d.get("epoch")
        return cls(d.get("exp_id", ""), d.get("state", "pending"),
                   d.get("name", ""), d.get("budget", 0),
                   d.get("observations", 0), d.get("failures", 0),
                   d.get("pending", 0), d.get("best"),
                   d.get("prefetched", 0), d.get("pump"),
                   list(epoch_tuple(epoch)) if epoch else None)


@dataclass
class StopRequest:
    """Terminate the experiment; pending suggestions are reclaimed."""
    exp_id: str
    state: str = "stopped"                  # stopped | deleted

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "state": self.state}

    @classmethod
    def from_json(cls, d) -> "StopRequest":
        return cls(d.get("exp_id", ""), d.get("state", "stopped"))


@dataclass
class BestRequest:
    exp_id: str

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id}

    @classmethod
    def from_json(cls, d) -> "BestRequest":
        return cls(d.get("exp_id", ""))


@dataclass
class BestResponse:
    best: Optional[Dict[str, Any]] = None   # Observation.to_json()

    def to_json(self) -> Dict[str, Any]:
        return {"best": self.best}

    @classmethod
    def from_json(cls, d) -> "BestResponse":
        return cls(d.get("best"))


# --------------------------------------------------------------- batching
# Multiplexed transport plane (additive v1, API.md §Transport batching):
# a BatchRequest carries an *ordered* list of typed data-plane ops
# (observe / report / release / requeue) and is applied per experiment in
# op order, so one wire round trip replaces N.  ``batch_id`` is client-
# assigned and unique per batch; the server keeps a bounded dedupe window
# of applied batches so a transport-level retry of the same batch_id
# replays the recorded per-op results instead of re-applying — batches
# are exactly-once even though the POST is retried like any idempotent
# verb.  Each op answers individually: ``ok`` + the op's normal response
# payload, or a typed error (e.g. every op of a fenced zombie's batch
# answers ``fenced`` — item-by-item, never partially ghost-applied).

BATCH_OP_KINDS = ("observe", "report", "release", "requeue")


@dataclass
class BatchOp:
    """One typed op inside a batch.  ``seq`` is the client's per-batch
    position (dense, 0-based) — results echo it so a caller can match
    them back without relying on list order."""
    seq: int
    op: str                                 # one of BATCH_OP_KINDS
    payload: Dict[str, Any]                 # the op's request to_json()

    def to_json(self) -> Dict[str, Any]:
        return {"seq": self.seq, "op": self.op, "payload": self.payload}

    @classmethod
    def from_json(cls, d) -> "BatchOp":
        op = d.get("op")
        if op not in BATCH_OP_KINDS:
            raise ApiError(E_BAD_REQUEST, f"unknown batch op {op!r}")
        return cls(int(d.get("seq", 0)), op, d.get("payload") or {})


@dataclass
class BatchRequest:
    batch_id: str
    ops: List[BatchOp] = field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return {"version": PROTOCOL_VERSION, "batch_id": self.batch_id,
                "ops": [o.to_json() for o in self.ops]}

    @classmethod
    def from_json(cls, d) -> "BatchRequest":
        if not d.get("batch_id"):
            raise ApiError(E_BAD_REQUEST, "batch requires 'batch_id'")
        return cls(d["batch_id"],
                   [BatchOp.from_json(o) for o in d.get("ops", [])])


@dataclass
class BatchOpResult:
    """Per-op outcome: ``result`` is the op's normal response JSON when
    ``ok``, ``error`` is an ``{"code", "message"}`` pair otherwise (same
    codes as the unbatched endpoints — API.md §Transport batching has the
    per-op error table)."""
    seq: int
    ok: bool
    result: Optional[Dict[str, Any]] = None
    error: Optional[Dict[str, Any]] = None

    @classmethod
    def success(cls, seq: int, result: Dict[str, Any]) -> "BatchOpResult":
        return cls(seq, True, result=result)

    @classmethod
    def failure(cls, seq: int, err: ApiError) -> "BatchOpResult":
        return cls(seq, False,
                   error={"code": err.code, "message": err.message})

    @property
    def error_code(self) -> Optional[str]:
        return (self.error or {}).get("code") if not self.ok else None

    def to_json(self) -> Dict[str, Any]:
        return {"seq": self.seq, "ok": self.ok, "result": self.result,
                "error": self.error}

    @classmethod
    def from_json(cls, d) -> "BatchOpResult":
        return cls(int(d.get("seq", 0)), bool(d.get("ok")),
                   d.get("result"), d.get("error"))


@dataclass
class BatchResponse:
    """``replayed`` marks a dedupe-window hit: the batch was already
    applied and these are the recorded results of the first
    application."""
    batch_id: str
    results: List[BatchOpResult] = field(default_factory=list)
    replayed: bool = False

    def to_json(self) -> Dict[str, Any]:
        return {"batch_id": self.batch_id,
                "results": [r.to_json() for r in self.results],
                "replayed": self.replayed}

    @classmethod
    def from_json(cls, d) -> "BatchResponse":
        return cls(d.get("batch_id", ""),
                   [BatchOpResult.from_json(r) for r in d.get("results", [])],
                   bool(d.get("replayed", False)))


# ------------------------------------------------------------------- fleet
# Messages for the fleet control plane (repro.fleet): shards and
# schedulers heartbeat to the FleetManager, which answers with the
# current shard-map version so clients know when to re-route.  See
# API.md §Fleet.

@dataclass
class RequeueRequest:
    """Hand a *pending* suggestion back to the serving queue (dead-worker
    recovery): the suggestion keeps its id and its constant-liar lie, and
    the next ``suggest`` on this experiment serves it — exactly once —
    before any fresh speculation.

    ``assignment`` is the *transfer* form (rebalance handover): when the
    suggestion id is unknown to the receiving shard — it was minted by the
    previous owner — the assignment lets the new owner install it as a
    parked pending under the same id instead of rejecting it."""
    exp_id: str
    suggestion_id: str
    assignment: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id, "suggestion_id": self.suggestion_id,
                "assignment": self.assignment}

    @classmethod
    def from_json(cls, d) -> "RequeueRequest":
        if "suggestion_id" not in d:
            raise ApiError(E_BAD_REQUEST, "requeue requires 'suggestion_id'")
        return cls(d.get("exp_id", ""), d["suggestion_id"],
                   d.get("assignment"))


@dataclass
class DrainRequest:
    """Quiesce one experiment on its current owner ahead of a handover:
    stop the prefetch pump, retire the speculative queue, park the pending
    set, and answer with the parked suggestions so the manager can
    transfer them to the new owner.  Idempotent; a drained experiment
    answers ``wrong_shard`` to later data-plane calls so clients re-route."""
    exp_id: str

    def to_json(self) -> Dict[str, Any]:
        return {"exp_id": self.exp_id}

    @classmethod
    def from_json(cls, d) -> "DrainRequest":
        return cls(d.get("exp_id", ""))


@dataclass
class DrainResponse:
    drained: bool = False
    pending: List[Suggestion] = field(default_factory=list)
    observations: int = 0

    def to_json(self) -> Dict[str, Any]:
        return {"drained": self.drained,
                "pending": [s.to_json() for s in self.pending],
                "observations": self.observations}

    @classmethod
    def from_json(cls, d) -> "DrainResponse":
        return cls(d.get("drained", False),
                   [Suggestion.from_json(s) for s in d.get("pending", [])],
                   d.get("observations", 0))


@dataclass
class HeartbeatRequest:
    """One liveness beat from a worker (a scheduler process or a shard).
    ``holdings`` maps exp_id -> the pending suggestion_ids this worker
    currently holds; the manager requeues exactly these if the worker is
    later declared dead."""
    worker_id: str
    kind: str = "scheduler"                 # scheduler | shard
    holdings: Dict[str, List[str]] = field(default_factory=dict)
    seq: int = 0                            # per-worker beat counter

    def to_json(self) -> Dict[str, Any]:
        return {"worker_id": self.worker_id, "kind": self.kind,
                "holdings": self.holdings, "seq": self.seq}

    @classmethod
    def from_json(cls, d) -> "HeartbeatRequest":
        if "worker_id" not in d:
            raise ApiError(E_BAD_REQUEST, "heartbeat requires 'worker_id'")
        return cls(d["worker_id"], d.get("kind", "scheduler"),
                   {k: list(v) for k, v in (d.get("holdings") or {}).items()},
                   int(d.get("seq", 0)))


@dataclass
class HeartbeatResponse:
    """``map_version`` lets a client detect shard-map changes without
    polling ``/fleet/map``; ``period`` is the manager-prescribed beat
    interval (seconds)."""
    state: str = "alive"                    # registered|alive|suspect|dead
    map_version: int = 0
    period: float = 1.0

    def to_json(self) -> Dict[str, Any]:
        return {"state": self.state, "map_version": self.map_version,
                "period": self.period}

    @classmethod
    def from_json(cls, d) -> "HeartbeatResponse":
        return cls(d.get("state", "alive"), int(d.get("map_version", 0)),
                   float(d.get("period", 1.0)))


@dataclass
class ShardMap:
    """Versioned routing table: consistent-hash ownership plus explicit
    per-experiment overrides (admission-control redirects and failover
    reassignments).  The version increments on every membership or
    override change; clients treat a version bump as 'recompute all
    routes'."""
    version: int = 0
    shards: Dict[str, str] = field(default_factory=dict)   # shard_id -> url
    overrides: Dict[str, str] = field(default_factory=dict)  # exp -> shard_id

    def to_json(self) -> Dict[str, Any]:
        return {"version": self.version, "shards": self.shards,
                "overrides": self.overrides}

    @classmethod
    def from_json(cls, d) -> "ShardMap":
        return cls(int(d.get("version", 0)), dict(d.get("shards") or {}),
                   dict(d.get("overrides") or {}))
